"""known-good: client frames and handler reads agree."""


class Server:
    def __init__(self, store):
        self.store = store

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "store":
            value = msg["payload"]
            return {"ok": True, "stored": bool(value)}
        if op == "fetch":
            return {"ok": True, "value": msg.get("key")}
        return {"ok": False, "error": f"bad op {op}"}


def _request(host, port, token, msg):
    raise NotImplementedError


def client_store(value):
    return _request("h", 1, "t", {"op": "store", "payload": value})


def client_fetch(key):
    return _request("h", 1, "t", {"op": "fetch", "key": key})
