"""arctic-480b  [hf:Snowflake/snowflake-arctic-base]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
+ dense residual FFN. Trains with Adafactor (AdamW moments would need ~3.8TB
fp32 -- cannot fit 256 x 16GB; see DESIGN.md)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, dense_residual_ff=2 * 7168),
    optimizer="adafactor",
    fsdp=True,
    pad_heads_to=64,
    kv_replication=2,
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, dense_residual_ff=96),
    optimizer="adafactor",
)
