"""known-good: batch sub-ops (queued AND inline) line up with the
handler set -- the repaired twin of wire_batch_bad.py."""


class Server:
    def __init__(self):
        self.acks = []

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "ack":
            self.acks.append(msg["task"])
            return {"ok": True}
        if op == "poll":
            return {"ok": True, "task": None}
        if op == "batch":
            return {"ok": True,
                    "replies": [self.dispatch(s)
                                for s in msg.get("ops") or []]}
        return {"ok": False, "error": f"bad op {op}"}


def _request(host, port, token, msg):
    raise NotImplementedError


def client_poll(pending):
    pending.append({"op": "ack", "task": "t1", "worker": "w"})
    return _request("h", 1, "t",
                    {"op": "batch", "ops": pending + [{"op": "poll"}]})
