"""Assigned input shapes. Every architecture is exercised against each of
these cells (unless skipped per DESIGN.md §Arch-applicability)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable(arch_family: str, sub_quadratic: bool, shape_name: str) -> bool:
    """long_500k needs sub-quadratic attention; all our archs have decoders."""
    if shape_name == "long_500k":
        return sub_quadratic
    return True
