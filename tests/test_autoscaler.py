"""Autoscaler policy + scheduler fast-path tests.

Covers: scale-up on queue depth, gang scale-up for STRICT_SPREAD placement
groups, idle scale-down with cooldown, the indexed-placement == linear-scan
equivalence property, the backend provision/release hooks (render-only and
in-process), and an end-to-end elastic run on both the virtual-clock and
threaded backends."""
import random
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (Autoscaler, AutoscalerConfig, ContainerSpec,
                        Scheduler, SchedulerConfig, SimCluster, SimCostModel,
                        SyndeoCluster, TaskSpec, TaskState, WorkerInfo)
from repro.core.backends.base import AllocationRequest, Backend
from repro.core.backends.gcp_tpu import GcpTpuBackend
from repro.core.backends.kubernetes import KubernetesBackend
from repro.core.backends.local import LocalBackend, SimBackend
from repro.core.backends.slurm import SlurmBackend
from repro.core.object_store import GlobalObjectStore, NodeStore
from repro.core.task_graph import Task


def _mk_scheduler(mode="indexed", clock=None):
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(placement_mode=mode,
                                             enable_speculation=False),
                      clock=clock or time.monotonic)
    return store, sched


# ------------------------------------------------------------ policy: scale-up

def test_scale_up_on_queue_depth():
    _, sched = _mk_scheduler()
    for i in range(2):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    requests = []
    auto = Autoscaler(sched, lambda n, res: requests.append(n) or n,
                      lambda wids: None,
                      AutoscalerConfig(max_workers=16,
                                       queue_depth_per_worker=2.0,
                                       scale_up_cooldown_s=0.0))
    for i in range(12):   # 2 run, 10 queue -> backlog 10 > 2 * 2
        sched.submit(TaskSpec(fn=None))
    ev = auto.tick()
    assert ev is not None and ev.action == "scale_up"
    assert requests and requests[0] >= 1
    # in-flight request is counted: an immediate second tick must not stack
    assert auto.tick() is None or auto.events[-1].count <= 16


def test_scale_up_respects_max_workers():
    _, sched = _mk_scheduler()
    sched.add_worker(WorkerInfo("w0", {"cpu": 1.0}))
    requests = []
    auto = Autoscaler(sched, lambda n, res: requests.append(n) or n,
                      lambda wids: None,
                      AutoscalerConfig(max_workers=3,
                                       queue_depth_per_worker=1.0,
                                       scale_up_cooldown_s=0.0))
    for _ in range(50):
        sched.submit(TaskSpec(fn=None))
    auto.tick()
    assert sum(requests) <= 2      # 1 live + 2 = max_workers


def test_gang_scale_up_strict_spread():
    """An unsatisfiable STRICT_SPREAD gang parks as pending demand and the
    autoscaler requests enough distinct workers to bind it."""
    _, sched = _mk_scheduler()
    for i in range(2):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    bundles = [{"cpu": 1.0}] * 4
    assert not sched.request_placement_group("gang", bundles, "STRICT_SPREAD")
    assert "gang" in sched.pending_placement_groups()

    requests = []
    auto = Autoscaler(sched, lambda n, res: requests.append(n) or n,
                      lambda wids: None,
                      AutoscalerConfig(max_workers=16,
                                       scale_up_cooldown_s=0.0))
    ev = auto.tick()
    assert ev is not None and ev.action == "scale_up"
    assert sum(requests) >= 2      # 4 bundles - 2 live workers
    # when the workers join, the parked gang binds automatically
    for i in range(2, 4):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    assert "gang" not in sched.pending_placement_groups()
    assert len(set(sched.placement_binding("gang").values())) == 4


def test_scale_up_bootstraps_from_zero_workers():
    """A small backlog with an empty pool must still provision (the
    queue-depth threshold alone would tolerate it forever)."""
    _, sched = _mk_scheduler()
    requests = []
    auto = Autoscaler(sched, lambda n, res: requests.append(n) or n,
                      lambda wids: None,
                      AutoscalerConfig(min_workers=0, max_workers=8,
                                       queue_depth_per_worker=2.0,
                                       scale_up_cooldown_s=0.0))
    sched.submit(TaskSpec(fn=None))
    ev = auto.tick()
    assert ev is not None and ev.action == "scale_up"
    assert sum(requests) >= 1


def test_utilization_policy_needs_backlog():
    """Fully-busy workers with nothing queued must NOT provision -- the new
    workers would idle and be retired, flapping forever."""
    _, sched = _mk_scheduler()
    for i in range(2):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    for _ in range(2):
        sched.submit(TaskSpec(fn=None))   # both run, backlog 0
    requests = []
    auto = Autoscaler(sched, lambda n, res: requests.append(n) or n,
                      lambda wids: None,
                      AutoscalerConfig(max_workers=16,
                                       target_utilization=0.75,
                                       scale_up_cooldown_s=0.0))
    assert auto.tick() is None
    assert not requests


def test_synchronous_provision_leaves_no_phantom_pending():
    """A backend that joins workers inside provision_fn (threaded local)
    calls note_joined before provision_fn returns; the in-flight counter
    must come back to zero, not stick as phantom capacity."""
    _, sched = _mk_scheduler()
    sched.add_worker(WorkerInfo("w0", {"cpu": 1.0}))
    auto = Autoscaler(sched, lambda n, res: None, lambda wids: None,
                      AutoscalerConfig(max_workers=16,
                                       queue_depth_per_worker=1.0,
                                       scale_up_cooldown_s=0.0))

    def provision(n, res):
        for i in range(n):
            sched.add_worker(WorkerInfo(f"p{i}", {"cpu": 1.0}))
            auto.note_joined(f"p{i}")
        return n

    auto.provision_fn = provision
    for _ in range(8):
        sched.submit(TaskSpec(fn=None))
    ev = auto.tick()
    assert ev is not None and ev.action == "scale_up"
    assert auto._pending_provision == 0


# ---------------------------------------------------------- policy: scale-down

def test_idle_scale_down_with_cooldown():
    tnow = [0.0]
    _, sched = _mk_scheduler(clock=lambda: tnow[0])
    for i in range(4):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    released = []
    auto = Autoscaler(sched, lambda n, res: n, released.extend,
                      AutoscalerConfig(min_workers=1, idle_timeout_s=5.0,
                                       scale_down_cooldown_s=10.0,
                                       max_scale_down_step=1),
                      clock=lambda: tnow[0])
    assert auto.tick() is None         # idle timer starts now
    tnow[0] = 3.0
    assert auto.tick() is None         # not idle long enough
    tnow[0] = 6.0
    ev = auto.tick()
    assert ev is not None and ev.action == "scale_down" and ev.count == 1
    tnow[0] = 8.0
    assert auto.tick() is None         # blocked by the scale-down cooldown
    tnow[0] = 17.0
    assert auto.tick().action == "scale_down"
    assert len(released) == 2
    assert len(sched.workers) == 2


def test_scale_down_never_below_min_and_skips_busy():
    tnow = [100.0]
    _, sched = _mk_scheduler(clock=lambda: tnow[0])
    for i in range(3):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    t = sched.submit(TaskSpec(fn=None))          # occupies one worker
    assert t.state == TaskState.RUNNING
    released = []
    auto = Autoscaler(sched, lambda n, res: n, released.extend,
                      AutoscalerConfig(min_workers=2, idle_timeout_s=0.0,
                                       scale_down_cooldown_s=0.0,
                                       max_scale_down_step=8),
                      clock=lambda: tnow[0])
    tnow[0] = 200.0
    auto.tick()
    assert len(sched.workers) == 2               # only one victim allowed
    assert t.worker in sched.workers             # the busy worker survives


def test_retire_worker_refuses_busy_and_gang_bound():
    _, sched = _mk_scheduler()
    for i in range(3):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    t = sched.submit(TaskSpec(fn=None))
    assert not sched.retire_worker(t.worker)     # busy
    assert sched.request_placement_group("pg", [{"cpu": 1.0}], "STRICT_SPREAD")
    bound = next(iter(sched.placement_binding("pg").values()))
    if bound != t.worker:
        assert not sched.retire_worker(bound)    # gang-bound
    free = next(w for w in list(sched.workers)
                if w != t.worker and w != bound)
    assert sched.retire_worker(free)
    assert free not in sched.workers


# ------------------------------------------------- indexed == linear placement

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 40), st.integers(0, 30))
def test_indexed_placement_matches_linear_scan(seed, n_workers, n_busy):
    """Property: the heap fast-path picks exactly the worker the seed's
    linear scan would pick (same load/registration-order tie-breaking),
    including infeasible and heterogeneous-resource cases."""
    rng = random.Random(seed)
    store, sched = _mk_scheduler(mode="indexed")
    for i in range(n_workers):
        res = {"cpu": float(rng.choice([1, 2, 4]))}
        if rng.random() < 0.3:
            res["gpu"] = float(rng.choice([1, 2]))
        store.register_node(NodeStore(f"w{i}"))
        sched.add_worker(WorkerInfo(f"w{i}", res))
    # random occupancy, index kept in sync exactly as schedule() does
    workers = list(sched.workers.values())
    for _ in range(n_busy):
        w = rng.choice(workers)
        req = {"cpu": float(rng.choice([1, 2]))}
        if w.fits(req):
            w.acquire(req)
            sched.index.touch(w)
    # sprinkle objects for locality-scored picks
    deps = []
    for _ in range(rng.randrange(3)):
        holder = rng.choice(workers).id
        deps.append(store.put(holder, b"x" * rng.randrange(1, 2048)))
    req = {"cpu": float(rng.choice([1, 2, 4]))}
    if rng.random() < 0.3:
        req["gpu"] = 1.0
    task = Task(spec=TaskSpec(fn=None, resources=req),
                deps=deps if rng.random() < 0.5 else [])
    got = sched._pick_worker_indexed(task)
    want = sched._pick_worker_linear(task)
    assert (got.id if got else None) == (want.id if want else None)


def test_index_survives_churn():
    """Placement stays correct through add/remove/fail/retire churn."""
    rng = random.Random(7)
    _, sched = _mk_scheduler(mode="indexed")
    alive = []
    for step in range(200):
        op = rng.random()
        if op < 0.4 or len(alive) < 2:
            wid = f"w{step}"
            sched.add_worker(WorkerInfo(wid, {"cpu": float(rng.choice([1, 2]))}))
            alive.append(wid)
        elif op < 0.55:
            sched.on_worker_failed(alive.pop(rng.randrange(len(alive))))
        elif op < 0.7:
            wid = alive[rng.randrange(len(alive))]
            if sched.retire_worker(wid):
                alive.remove(wid)
        else:
            t = Task(spec=TaskSpec(fn=None, resources={"cpu": 1.0}))
            got = sched._pick_worker_indexed(t)
            want = sched._pick_worker_linear(t)
            assert (got.id if got else None) == (want.id if want else None)
    assert len(sched.index) == len(sched.workers)


# ------------------------------------------------------------- backend hooks

def _req():
    return AllocationRequest(nodes=4, cpus_per_node=28,
                             shared_dir="/shared/syndeo")


def test_slurm_elastic_artifacts():
    b = SlurmBackend(ContainerSpec())
    assert b.supports_elastic
    up = b.provision_workers(_req(), "abc123", 3)
    sbatch = next(iter(up.values()))
    assert "#SBATCH --nodes=3" in sbatch
    assert "apptainer exec" in sbatch and "--role worker" in sbatch
    assert "--role head" not in sbatch    # worker-only job: the head stays put
    down = b.release_workers(_req(), "abc123", ["node7", "node9"])
    sh = next(iter(down.values()))
    assert "State=DRAIN" in sh and "node7" in sh and "node9" in sh
    # worker ids are resolved to hostnames through the rendezvous mapping
    # before any scontrol/scancel touches them
    assert "$MAP/node7.host" in sh and "$MAP/node9.host" in sh
    # scancel is scoped to the resolved hosts, not every scale-up batch
    assert "--nodelist=$HOSTS" in sh


def test_slurm_scale_up_singleton_and_reservation():
    """Elastic gang growth is guaranteed, not hopeful: scale-up jobs share
    a job name and serialize under --dependency=singleton, and an optional
    standing reservation pins the capacity they draw from."""
    b = SlurmBackend(ContainerSpec())
    up = next(iter(b.provision_workers(_req(), "abc123", 2).values()))
    assert "#SBATCH --dependency=singleton" in up
    assert "#SBATCH --job-name=syndeo-abc123-scaleup" in up
    assert "--reservation" not in up         # optional: absent when unset
    req = AllocationRequest(nodes=4, cpus_per_node=28,
                            shared_dir="/shared/syndeo",
                            reservation="syndeo-pool")
    up2 = next(iter(b.provision_workers(req, "abc123", 2).values()))
    assert "#SBATCH --reservation=syndeo-pool" in up2
    assert "#SBATCH --dependency=singleton" in up2
    # the base allocation honors the reservation too
    boot = b.render_artifacts(req, "abc123")["submit_abc123.sbatch"]
    assert "#SBATCH --reservation=syndeo-pool" in boot


def test_k8s_elastic_artifacts():
    b = KubernetesBackend(ContainerSpec())
    up = next(iter(b.provision_workers(_req(), "abc123", 5).values()))
    # declarative scaling: the HPA owns the replica count, the hook only
    # nudges its floor -- never an imperative `kubectl scale`
    assert "kubectl patch hpa syndeo-workers-abc123" in up
    assert "kubectl scale" not in up
    assert "CUR + 5" in up
    down = next(iter(b.release_workers(_req(), "abc123",
                                       ["pod-a", "pod-b"]).values()))
    assert "CUR - 2" in down
    assert "kubectl scale" not in down
    # victims are marked for deletion *before* the shrink so the controller
    # removes exactly those pods, not arbitrary busy ones
    assert "pod-deletion-cost" in down
    assert down.index("pod-deletion-cost") < down.index("kubectl patch hpa")


def test_k8s_hpa_and_metrics_adapter_manifests():
    """The bring-up artifacts include a HorizontalPodAutoscaler fed by the
    scheduler's backlog/utilization signals through a custom-metrics
    adapter (the declarative replacement for the kubectl-scale script)."""
    b = KubernetesBackend(ContainerSpec())
    arts = b.render_artifacts(_req(), "abc123")
    hpa = arts["syndeo_hpa_abc123.yaml"]
    assert "kind: HorizontalPodAutoscaler" in hpa
    assert "name: syndeo-workers-abc123" in hpa      # targets the Deployment
    assert "syndeo_backlog_per_worker" in hpa
    assert "syndeo_busy_fraction" in hpa
    adapter = arts["syndeo_metrics_adapter_abc123.yaml"]
    assert "custom.metrics.k8s.io" in adapter
    assert "repro.core.metrics_adapter" in adapter
    assert "runAsNonRoot: true" in adapter           # the Apptainer principle


def test_gcp_tpu_elastic_artifacts():
    b = GcpTpuBackend(ContainerSpec())
    up = next(iter(b.provision_workers(_req(), "abc123", 2).values()))
    assert "queued-resources create" in up
    assert "--role worker" in up and "--privileged=false" in up
    down = next(iter(b.release_workers(_req(), "abc123",
                                       ["syndeo-abc123-3"]).values()))
    assert "queued-resources delete syndeo-abc123-3" in down


def test_gcp_tpu_release_prefers_reverse_join_order():
    """Released slices are deleted most-recently-joined first, so pod 0
    (the jax.distributed coordinator) and the low ranks stay stable."""
    b = GcpTpuBackend(ContainerSpec())
    ids = ["syndeo-abc123-1", "syndeo-abc123-7", "syndeo-abc123-3"]
    down = next(iter(b.release_workers(_req(), "abc123", ids).values()))
    pos = {wid: down.index(f"queued-resources delete {wid}") for wid in ids}
    assert pos["syndeo-abc123-7"] < pos["syndeo-abc123-3"] \
        < pos["syndeo-abc123-1"]


def test_release_workers_renders_drain_deadline():
    """The drain deadline reaches every backend's release artifact."""
    gcp = next(iter(GcpTpuBackend(ContainerSpec()).release_workers(
        _req(), "abc123", ["syndeo-abc123-2"],
        drain_deadline_s=120.0).values()))
    assert "sleep 120" in gcp
    slurm = next(iter(SlurmBackend(ContainerSpec()).release_workers(
        _req(), "abc123", ["node3"], drain_deadline_s=60.0).values()))
    assert "sleep 60" in slurm
    k8s = next(iter(KubernetesBackend(ContainerSpec()).release_workers(
        _req(), "abc123", ["pod-a"], drain_deadline_s=30.0).values()))
    assert "sleep 30" in k8s
    # the deletion wait covers the HPA's 120s scaleDown stabilization window
    assert "--timeout=210s" in k8s


def test_slurm_worker_id_hostname_reconciliation():
    """Workers join under $(hostname) and record the id -> host mapping, so
    the scale-down artifact drains exactly the right nodes."""
    b = SlurmBackend(ContainerSpec())
    boot = b.render_artifacts(_req(), "abc123")
    sbatch = boot["submit_abc123.sbatch"]
    assert '--worker-id "$(hostname)"' in sbatch
    assert "rdv/workers/$(hostname).host" in sbatch
    up = next(iter(b.provision_workers(_req(), "abc123", 2).values()))
    assert '--worker-id "$(hostname)"' in up
    assert "rdv/workers/$(hostname).host" in up


def test_backend_cooldown_defaults():
    """gcp_tpu cooldowns are minutes-scale (queued-resource latency);
    local/sim react in seconds; overrides win."""
    gcp = AutoscalerConfig.for_backend("gcp_tpu")
    assert gcp.scale_up_cooldown_s >= 60.0
    assert gcp.scale_down_cooldown_s >= 300.0
    assert gcp.idle_timeout_s >= 60.0
    assert gcp.release_order == "reverse_join"
    for name in ("local", "sim"):
        cfg = AutoscalerConfig.for_backend(name)
        assert cfg.scale_up_cooldown_s <= 5.0
        assert cfg.scale_down_cooldown_s <= 60.0
        assert cfg.release_order == "idle"
    assert AutoscalerConfig.for_backend("gcp_tpu",
                                        max_workers=4).max_workers == 4


def test_reverse_join_release_order_picks_newest_workers():
    """With release_order="reverse_join", ripe victims are the most
    recently joined workers, not the longest idle."""
    tnow = [0.0]
    _, sched = _mk_scheduler(clock=lambda: tnow[0])
    for i in range(4):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    released = []
    auto = Autoscaler(sched, lambda n, res: n, released.extend,
                      AutoscalerConfig(min_workers=2, idle_timeout_s=0.0,
                                       scale_down_cooldown_s=0.0,
                                       max_scale_down_step=8,
                                       release_order="reverse_join"),
                      clock=lambda: tnow[0])
    tnow[0] = 10.0
    ev = auto.tick()
    assert ev is not None and ev.action == "scale_down"
    assert released == ["w3", "w2"]          # newest first, min kept
    assert set(sched.workers) == {"w0", "w1"}


def test_base_backend_not_elastic_by_default():
    class Dummy(Backend):
        name = "dummy"

        def render_artifacts(self, req, cluster_id):
            return {}

    with pytest.raises(NotImplementedError):
        Dummy(ContainerSpec()).provision_workers(_req(), "x", 1)


def test_sim_backend_provisions_into_simcluster():
    cost = SimCostModel(task_time_s=lambda s: 0.1)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    sim.add_workers(1)
    b = SimBackend(ContainerSpec(), sim, provision_delay_s=0.5)
    b.provision_workers(AllocationRequest(nodes=1, cpus_per_node=1),
                        "abc123", 3)
    assert len(sim.scheduler.workers) == 1     # join is delayed
    sim.run()
    assert len(sim.scheduler.workers) == 4


# ------------------------------------------------------- drain-before-release

def test_autoscaler_scale_down_drains_and_migrates():
    """Idle scale-down on the sim backend with worker-resident objects:
    the victims' objects migrate to survivors (no recompute) before the
    release event fires."""
    cost = SimCostModel(task_time_s=lambda s: 0.2, result_bytes=lambda s: 512.0,
                        jitter=0.0, result_location="worker")
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    sim.add_workers(6)
    sim.attach_autoscaler(
        AutoscalerConfig(min_workers=2, max_workers=6,
                         idle_timeout_s=1.0, scale_down_cooldown_s=0.5,
                         max_scale_down_step=8, drain_deadline_s=2.0),
        provision_delay_s=0.3)
    ids = sim.run_scenario(
        [(0.1, TaskSpec(fn=None, max_retries=10)) for _ in range(12)],
        tick_every=0.1, drain_s=6.0)
    assert {sim.scheduler.graph.tasks[i].state for i in ids} \
        == {TaskState.FINISHED}
    assert len(sim.scheduler.workers) == 2       # drained back to min
    downs = [e for e in sim.autoscaler.events if e.action == "scale_down"]
    assert downs and sum(e.count for e in downs) == 4
    # released workers' outputs were migrated, not dropped: all readable
    for i in ids:
        out = sim.scheduler.graph.tasks[i].output
        assert sim.store.locations(out) <= set(sim.scheduler.workers) | {"head"}
        sim.store.get("head", out)
    assert sim.store.stats["reconstructions"] == 0
    assert sim.scheduler.stats["drained"] == 4


def test_backlog_cancels_inflight_drains():
    """Demand returning while a drain is in flight un-drains the worker
    instead of releasing + re-provisioning."""
    tnow = [0.0]
    _, sched = _mk_scheduler(clock=lambda: tnow[0])
    for i in range(3):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    released = []
    auto = Autoscaler(sched, lambda n, res: n, released.extend,
                      AutoscalerConfig(min_workers=1, idle_timeout_s=1.0,
                                       scale_down_cooldown_s=0.0,
                                       max_scale_down_step=8),
                      clock=lambda: tnow[0])
    # pin the drains open: pretend migrations are in flight
    sched.migrate_fn = lambda wid, ref, dst: None
    tnow[0] = 5.0
    auto.tick()
    # force at least one drain to stay open by marking a pending move
    if auto._draining:
        wid = next(iter(auto._draining))
        sched._drains[wid].pending.add("synthetic-object")
        for _ in range(6):
            sched.submit(TaskSpec(fn=None))
        tnow[0] = 6.0
        auto.tick()
        assert wid not in auto._draining          # drain cancelled
        assert not sched.workers[wid].draining    # placeable again
    # make the no-op explicit if every drain completed synchronously:
    # idle workers without objects release immediately, which is also fine


def test_drained_release_reaches_backend_hook():
    """SimBackend.release_workers drains workers still registered instead
    of dropping them."""
    cost = SimCostModel(task_time_s=lambda s: 0.05, jitter=0.0,
                        result_location="worker")
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    sim.add_workers(3)
    sim.run_wave([TaskSpec(fn=None) for _ in range(6)])
    b = SimBackend(ContainerSpec(), sim)
    b.release_workers(AllocationRequest(nodes=1), "abc123", ["w0"],
                      drain_deadline_s=1.0)
    sim.run()
    assert "w0" not in sim.scheduler.workers
    assert sim.store.stats["reconstructions"] == 0


# --------------------------------------------------------------- end to end

def test_sim_elastic_burst_scales_up_and_down():
    cost = SimCostModel(task_time_s=lambda s: 0.5, result_bytes=lambda s: 100.0)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    sim.add_workers(2)
    sim.attach_autoscaler(
        AutoscalerConfig(min_workers=2, max_workers=32,
                         queue_depth_per_worker=1.0, scale_up_cooldown_s=0.2,
                         max_scale_up_step=32, idle_timeout_s=1.0,
                         scale_down_cooldown_s=0.5, max_scale_down_step=32),
        provision_delay_s=0.3)
    ids = sim.run_scenario(
        [(0.5, TaskSpec(fn=None, group="burst")) for _ in range(60)],
        tick_every=0.1, drain_s=4.0)
    states = {sim.scheduler.graph.tasks[i].state for i in ids}
    assert states == {TaskState.FINISHED}
    actions = {e.action for e in sim.autoscaler.events}
    assert actions == {"scale_up", "scale_down"}
    assert max(e.workers_before + e.count for e in sim.autoscaler.events
               if e.action == "scale_up") > 2
    assert len(sim.scheduler.workers) == 2     # drained back to min


def test_threaded_cluster_autoscales():
    with SyndeoCluster() as cluster:
        cluster.add_worker()
        cluster.attach_autoscaler(AutoscalerConfig(
            min_workers=1, max_workers=6, queue_depth_per_worker=1.0,
            scale_up_cooldown_s=0.0, idle_timeout_s=60.0))
        tasks = [cluster.submit(time.sleep, 0.05) for _ in range(12)]
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            cluster.health_check()
            with cluster._lock:
                done = all(cluster.scheduler.graph.tasks[t.id].state
                           == TaskState.FINISHED for t in tasks)
            if done:
                break
            time.sleep(0.02)
        cluster.wait_all(tasks, timeout=10.0)
        assert len(cluster.scheduler.workers) > 1
        assert any(e.action == "scale_up" for e in cluster.autoscaler.events)


# ------------------------- GCP TPU queued-resource provisioning latency (sim)


def test_lognormal_provision_latency_is_heavy_tailed():
    """The sampler models queued-resource creation: minutes-scale median,
    a tail that occasionally lands an order of magnitude late."""
    from repro.core import lognormal_provision_latency
    rng = random.Random(11)
    sample = lognormal_provision_latency(median_s=120.0, sigma=1.0)
    draws = sorted(sample(rng) for _ in range(2000))
    median = draws[len(draws) // 2]
    p95 = draws[int(len(draws) * 0.95)]
    assert 90.0 < median < 160.0
    assert p95 > 3.0 * median          # heavy tail, not a fixed delay
    assert min(draws) >= 5.0           # floor: a slice never lands instantly


def _bursty_tpu_run(backend_name: str, seed: int = 3):
    """Periodic bursts under heavy-tailed provisioning: the per-backend
    cooldowns decide whether the pool survives inter-burst gaps or is
    churned (released, then re-waited-for minutes)."""
    from repro.core import lognormal_provision_latency
    cost = SimCostModel(task_time_s=lambda s: 5.0,
                        result_bytes=lambda s: 1024.0, jitter=0.0)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9), seed=seed)
    sim.set_provision_latency(lognormal_provision_latency(median_s=120.0,
                                                          sigma=1.0))
    cfg = AutoscalerConfig.for_backend(backend_name, min_workers=0,
                                       max_workers=8,
                                       queue_depth_per_worker=2.0)
    sim.attach_autoscaler(cfg)
    arrivals = []
    for burst in range(4):
        t0 = burst * 300.0
        arrivals += [(t0 + 0.1 * i, TaskSpec(fn=None, name=f"b{burst}-{i}"))
                     for i in range(16)]
    ids = sim.run_scenario(arrivals, tick_every=5.0, drain_s=30.0)
    assert all(sim.scheduler.graph.tasks[i].state == TaskState.FINISHED
               for i in ids)
    ups = [e for e in sim.autoscaler.events if e.action == "scale_up"]
    downs = [e for e in sim.autoscaler.events if e.action == "scale_down"]
    return sum(e.count for e in ups), sum(e.count for e in downs), sim.now


def test_gcp_tpu_cooldowns_hold_pool_through_provisioning_tail():
    """Sanity-check AutoscalerConfig.for_backend("gcp_tpu") against the
    modeled latency distribution: with minutes-scale idle timeouts and
    cooldowns the pool persists across 300s burst gaps (few provisions,
    little release churn), while the seconds-scale sim defaults release
    between bursts and then stall for another minutes-scale allocation."""
    prov_gcp, rel_gcp, span_gcp = _bursty_tpu_run("gcp_tpu")
    prov_sim, rel_sim, span_sim = _bursty_tpu_run("sim")
    # seconds-scale cooldowns churn: they re-provision what they released
    assert prov_sim > prov_gcp
    assert rel_sim > rel_gcp
    # the gcp config rides one allocation wave across all four bursts
    assert prov_gcp <= 10
    # churn pays the provisioning tail again: the workload finishes later
    assert span_gcp <= span_sim
