"""Reproduction of the paper's Tables I-IV / Figs 4-5.

The REAL Syndeo scheduler + object store run under the discrete-event
backend (virtual time) with a cost model calibrated entirely from the
paper's own numbers:
  * per-interaction compute cost  = 28 / throughput(28 CPUs)  (Table III),
  * result artifact size          = 1000 steps x obs_dim x 8 B  (float64
    observations, Gymnasium default),
  * head dispatch overhead + head link bandwidth: single global pair fit
    against the scaling curves (the head is one process on one node -- its
    serialization is the physical cause of the paper's efficiency decay,
    most visible for Humanoid's 376-float observations).

Each configuration is run 4 times with different seeds (as in the paper) to
report mean/std.
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

import numpy as np

from repro.core import SchedulerConfig, SimCluster, SimCostModel, TaskSpec
from repro.rl.envs import ENV_SPECS

CPU_CONFIGS = [28, 84, 196, 420, 868]
STEPS_PER_CPU = 1000

# head-model calibration: two constants fit on two paper endpoints
# (Pendulum@868 eff 64% -> 3.1 ms/task head dispatch; Humanoid@868 eff 9%
# -> ~40 MB/s effective head ingest incl. pickling), then held fixed for
# all 14 envs x 5 scales. See EXPERIMENTS.md for the validation table.
DISPATCH_OVERHEAD_S = 0.0031
HEAD_BANDWIDTH_BPS = 40e6

# paper Table I/III values for comparison
PAPER_SPEEDUP = {
    "Acrobot": [1, 3, 6, 11, 18], "Ant": [1, 3, 5, 8, 11],
    "Cartpole": [1, 2, 6, 8, 13], "HalfCheetah": [1, 3, 5, 9, 13],
    "Hopper": [1, 3, 6, 10, 16], "Humanoid": [1, 2, 3, 4, 3],
    "HumanoidStandup": [1, 2, 3, 3, 3],
    "InvertedDoublePendulum": [1, 2, 5, 9, 13],
    "InvertedPendulum": [1, 3, 6, 10, 17], "Pendulum": [1, 3, 7, 12, 20],
    "Pusher": [1, 3, 6, 9, 13], "Reacher": [1, 3, 6, 10, 13],
    "Swimmer": [1, 3, 6, 9, 12], "Walker2d": [1, 3, 6, 11, 15],
}
PAPER_THROUGHPUT_28 = {k: v for k, v in {
    "Acrobot": 5656, "Ant": 5106, "Cartpole": 6876, "HalfCheetah": 6343,
    "Hopper": 5505, "Humanoid": 4108, "HumanoidStandup": 3573,
    "InvertedDoublePendulum": 6265, "InvertedPendulum": 5864,
    "Pendulum": 5895, "Pusher": 5939, "Reacher": 6521, "Swimmer": 6168,
    "Walker2d": 5264}.items()}


def run_env_config(env: str, n_cpus: int, seed: int) -> float:
    """Virtual-time throughput (interactions/s) for one configuration."""
    spec = ENV_SPECS[env]
    cost = SimCostModel(
        task_time_s=lambda s: STEPS_PER_CPU * spec.step_cost_s,
        result_bytes=lambda s: STEPS_PER_CPU * spec.obs_dim * 8.0,
        dispatch_overhead_s=DISPATCH_OVERHEAD_S,
        head_bandwidth_Bps=HEAD_BANDWIDTH_BPS,
        jitter=0.06,
    )
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9), seed=seed)
    sim.add_workers(n_cpus)
    makespan = sim.run_wave([TaskSpec(fn=None, group=env)
                             for _ in range(n_cpus)])
    return n_cpus * STEPS_PER_CPU / makespan


def run_all(n_seeds: int = 4) -> Dict[str, Dict[int, Tuple[float, float]]]:
    out: Dict[str, Dict[int, Tuple[float, float]]] = {}
    for env in ENV_SPECS:
        out[env] = {}
        for n in CPU_CONFIGS:
            tputs = [run_env_config(env, n, seed) for seed in range(n_seeds)]
            out[env][n] = (float(np.mean(tputs)), float(np.std(tputs)))
    return out


def tables(results) -> Tuple[List[str], List[str], List[str]]:
    """Render Tables I (speedup), II (efficiency), III/IV (throughput)."""
    t1 = [f"{'Environment':26s}" + "".join(f"{n:>8d}" for n in CPU_CONFIGS)]
    t2 = [t1[0]]
    t34 = [f"{'Environment':26s}{'CPUs':>6s}{'mean':>10s}{'std':>8s}"
           f"{'ideal':>7s}{'actual':>8s}{'eff%':>6s}"]
    for env, per in results.items():
        base = per[CPU_CONFIGS[0]][0]
        sp_row, eff_row = f"{env:26s}", f"{env:26s}"
        for n in CPU_CONFIGS:
            mean, std = per[n]
            speedup = mean / base
            ideal = n / CPU_CONFIGS[0]
            eff = min(100.0, 100.0 * speedup / ideal)
            sp_row += f"{speedup:7.0f}x"
            eff_row += f"{eff:8.0f}"
            t34.append(f"{env:26s}{n:>6d}{mean:>10.0f}{std:>8.0f}"
                       f"{ideal:>6.0f}x{speedup:>7.0f}x{eff:>6.0f}")
        t1.append(sp_row)
        t2.append(eff_row)
    return t1, t2, t34


def compare_to_paper(results) -> Dict[str, float]:
    """Mean absolute speedup error vs the paper's Table I."""
    errs = {}
    for env, per in results.items():
        base = per[CPU_CONFIGS[0]][0]
        ours = [per[n][0] / base for n in CPU_CONFIGS]
        paper = PAPER_SPEEDUP[env]
        errs[env] = float(np.mean([abs(o - p) for o, p in
                                   zip(ours, paper)]))
    return errs


def main():
    results = run_all()
    t1, t2, t34 = tables(results)
    print("\n=== Table I: throughput speedup factors ===")
    print("\n".join(t1))
    print("\n=== Table II: efficiency percentages ===")
    print("\n".join(t2))
    errs = compare_to_paper(results)
    print("\n=== fidelity vs paper Table I (mean |speedup error|) ===")
    for env, e in sorted(errs.items()):
        print(f"  {env:26s} {e:5.2f}x")
    print(f"  {'OVERALL':26s} {np.mean(list(errs.values())):5.2f}x")
    return results


if __name__ == "__main__":
    main()
