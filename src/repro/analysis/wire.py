"""syndeo-lint pass 3: wire-protocol conformance.

Handlers are functions with ``op = msg.get("op")`` / ``msg["op"]``
dispatch chains (or inline ``header.get("op") == "put"`` tests); for
each op branch we record which envelope fields the handler *requires*
(``msg["field"]``), which it treats as optional (``msg.get(...)``) and
the literal reply dicts it returns.  Client sites are ``_request`` /
``_rpc`` calls carrying a dict payload with an ``"op"`` key (either a
dict literal argument, or a local variable assembled from a dict
literal plus ``var["k"] = ...`` updates).

Batch sub-ops are wire frames too: a dict literal carrying a constant
``"op"`` key that is queued for a later ``batch`` frame (via
``.append(...)``/``.extend(...)``) or written inline in the list under
an ``"ops"`` key is cross-checked exactly like a top-level client send
-- a malformed sub-op must fail lint here, not at dispatch time.

SYN-W001  op sent by a client but matched by no handler branch.
SYN-W002  field a handler requires that no client site for that op
          ever sends (ops never sent in the analyzed tree are skipped:
          they belong to out-of-tree callers such as operator tooling).
SYN-W003  literal reply dict with neither ``ok`` nor ``error``.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.model import CodeModel, Finding

CLIENT_CALL_NAMES = {"_request", "_rpc"}

#: list mutators that queue a sub-op for a later `batch` frame
BATCH_QUEUE_METHODS = {"append", "extend"}


@dataclass
class HandlerInfo:
    op: str
    file: str
    function: str
    line: int
    required: Dict[str, int] = field(default_factory=dict)  # field->line
    optional: Set[str] = field(default_factory=set)
    replies: List[Tuple[int, Set[str]]] = field(default_factory=list)


@dataclass
class SendSite:
    op: str
    file: str
    function: str
    line: int
    keys: Set[str] = field(default_factory=set)


def check_wire(model: CodeModel) -> List[Finding]:
    handlers: Dict[str, List[HandlerInfo]] = {}
    sends: List[SendSite] = []
    for fn in model.functions.values():
        for h in _extract_handlers(fn):
            handlers.setdefault(h.op, []).append(h)
        sends.extend(_extract_sends(fn))
        sends.extend(_extract_batch_subops(fn))

    findings: List[Finding] = []
    for s in sends:
        if s.op not in handlers:
            findings.append(Finding(
                "SYN-W001", s.file, s.line, s.function,
                f"op {s.op!r} sent but no handler branch matches"))

    sent_keys: Dict[str, Set[str]] = {}
    for s in sends:
        sent_keys.setdefault(s.op, set()).update(s.keys)
    for op, hs in handlers.items():
        if op not in sent_keys:
            continue  # only out-of-tree callers (operator ops)
        for h in hs:
            for fld, line in sorted(h.required.items()):
                if fld not in sent_keys[op]:
                    findings.append(Finding(
                        "SYN-W002", h.file, line, h.function,
                        f"handler for op {op!r} requires field "
                        f"{fld!r} never sent by any call site"))

    for hs in handlers.values():
        for h in hs:
            for line, keys in h.replies:
                if not keys & {"ok", "error"}:
                    findings.append(Finding(
                        "SYN-W003", h.file, line, h.function,
                        f"reply for op {h.op!r} has neither 'ok' nor "
                        f"'error' key"))
    return findings


# -- handler extraction ---------------------------------------------------


def _const_str(e: ast.AST) -> Optional[str]:
    if isinstance(e, ast.Constant) and isinstance(e.value, str):
        return e.value
    return None


def _reads_field(e: ast.AST) -> Optional[Tuple[str, str]]:
    """(msg var, field) for ``var["field"]`` or ``var.get("field")``."""
    if (isinstance(e, ast.Subscript)
            and isinstance(e.value, ast.Name)):
        fld = _const_str(e.slice)
        if fld is not None:
            return e.value.id, fld
    return None


def _op_read_var(e: ast.AST) -> Optional[str]:
    """msg var name when e is ``var.get("op")`` or ``var["op"]``."""
    if (isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute)
            and e.func.attr == "get" and e.args
            and isinstance(e.func.value, ast.Name)
            and _const_str(e.args[0]) == "op"):
        return e.func.value.id
    rf = _reads_field(e)
    if rf and rf[1] == "op":
        return rf[0]
    return None


def _branch_ops(test: ast.AST,
                opvars: Dict[str, str]) -> Optional[Tuple[str, List[str]]]:
    """(msg var, [ops]) when `test` compares an op against literals."""
    if not (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.ops[0], (ast.Eq, ast.In))):
        return None
    left = test.left
    msgvar = None
    if isinstance(left, ast.Name) and left.id in opvars:
        msgvar = opvars[left.id]
    else:
        msgvar = _op_read_var(left)
    if msgvar is None:
        return None
    cmp = test.comparators[0]
    ops: List[str] = []
    if isinstance(cmp, (ast.Tuple, ast.List, ast.Set)):
        for el in cmp.elts:
            v = _const_str(el)
            if v is not None:
                ops.append(v)
    else:
        v = _const_str(cmp)
        if v is not None:
            ops.append(v)
    return (msgvar, ops) if ops else None


def _reply_dicts(value: ast.AST) -> List[ast.Dict]:
    if isinstance(value, ast.Dict):
        return [value]
    if (isinstance(value, ast.Tuple) and value.elts
            and isinstance(value.elts[0], ast.Dict)):
        return [value.elts[0]]
    if isinstance(value, ast.Call):
        return [a for a in value.args if isinstance(a, ast.Dict)]
    return []


def _dict_keys(d: ast.Dict) -> Optional[Set[str]]:
    """Constant keys, or None when unknowable (** splat / computed)."""
    keys: Set[str] = set()
    for k in d.keys:
        if k is None:
            return None
        v = _const_str(k)
        if v is None:
            return None
        keys.add(v)
    return keys


def _extract_handlers(fn) -> List[HandlerInfo]:
    node = fn.node
    opvars: Dict[str, str] = {}  # op var name -> msg var name
    for st in ast.walk(node):
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)):
            mv = _op_read_var(st.value)
            if mv:
                opvars[st.targets[0].id] = mv
    out: List[HandlerInfo] = []
    for st in ast.walk(node):
        if not isinstance(st, ast.If):
            continue
        hit = _branch_ops(st.test, opvars)
        if not hit:
            continue
        msgvar, ops = hit
        for op in ops:
            info = HandlerInfo(op=op, file=fn.file,
                               function=fn.qualname, line=st.lineno)
            _collect_branch(info, st.body, msgvar)
            out.append(info)
    return out


def _collect_branch(info: HandlerInfo, stmts: List[ast.stmt],
                    msgvar: str) -> None:
    for st in stmts:
        for n in ast.walk(st):
            rf = _reads_field(n)
            if rf and rf[0] == msgvar and rf[1] != "op":
                info.required.setdefault(rf[1], n.lineno)
            if (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "get" and n.args
                    and isinstance(n.func.value, ast.Name)
                    and n.func.value.id == msgvar):
                fld = _const_str(n.args[0])
                if fld and fld != "op":
                    info.optional.add(fld)
            if isinstance(n, ast.Return) and n.value is not None:
                for d in _reply_dicts(n.value):
                    keys = _dict_keys(d)
                    if keys is not None:
                        info.replies.append((d.lineno, keys))


# -- client-site extraction ----------------------------------------------


def _extract_batch_subops(fn) -> List[SendSite]:
    """Send sites hiding inside `batch` frames: dict literals with a
    constant ``"op"`` key that are (a) queued through a list's
    ``.append``/``.extend`` for a later batch (the worker's pending-ack
    queue pattern) or (b) written inline in the list under an ``"ops"``
    key. Each becomes an ordinary SendSite so SYN-W001/W002 hold for
    sub-ops exactly as for top-level frames."""
    out: List[SendSite] = []

    def emit(d: ast.Dict):
        keys = _dict_keys(d)
        if keys is None or "op" not in keys:
            return
        op = None
        for k, v in zip(d.keys, d.values):
            if _const_str(k) == "op":
                op = _const_str(v)
        if op is None:
            return                 # dynamic sub-op name: nothing to check
        out.append(SendSite(op=op, file=fn.file, function=fn.qualname,
                            line=d.lineno, keys=keys))

    for n in ast.walk(fn.node):
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                and n.func.attr in BATCH_QUEUE_METHODS):
            for a in n.args:
                for d in ast.walk(a):
                    if isinstance(d, ast.Dict):
                        emit(d)
        elif isinstance(n, ast.Dict):
            for k, v in zip(n.keys, n.values):
                if k is not None and _const_str(k) == "ops":
                    for d in ast.walk(v):
                        if isinstance(d, ast.Dict):
                            emit(d)
    return out


def _extract_sends(fn) -> List[SendSite]:
    node = fn.node
    # local dict payloads: var -> constant keys (dict literal + later
    # ``var["k"] = ...`` updates, order-insensitive on purpose)
    local_dicts: Dict[str, Dict[str, Optional[str]]] = {}
    for st in ast.walk(node):
        if not (isinstance(st, ast.Assign) and len(st.targets) == 1):
            continue
        tgt = st.targets[0]
        if isinstance(tgt, ast.Name) and isinstance(st.value, ast.Dict):
            keys = _dict_keys(st.value)
            if keys is None:
                continue
            kv: Dict[str, Optional[str]] = {k: None for k in keys}
            for k, v in zip(st.value.keys, st.value.values):
                kv[_const_str(k)] = _const_str(v)
            local_dicts.setdefault(tgt.id, {}).update(kv)
        elif (isinstance(tgt, ast.Subscript)
              and isinstance(tgt.value, ast.Name)
              and tgt.value.id in local_dicts):
            fld = _const_str(tgt.slice)
            if fld is not None:
                local_dicts[tgt.value.id][fld] = _const_str(st.value)

    out: List[SendSite] = []
    for n in ast.walk(node):
        if not isinstance(n, ast.Call):
            continue
        cname = None
        if isinstance(n.func, ast.Name):
            cname = n.func.id
        elif isinstance(n.func, ast.Attribute):
            cname = n.func.attr
        if cname not in CLIENT_CALL_NAMES:
            continue
        for a in list(n.args) + [k.value for k in n.keywords]:
            payload: Optional[Dict[str, Optional[str]]] = None
            if isinstance(a, ast.Dict):
                keys = _dict_keys(a)
                if keys is not None and "op" in keys:
                    payload = {k: None for k in keys}
                    for k, v in zip(a.keys, a.values):
                        payload[_const_str(k)] = _const_str(v)
            elif (isinstance(a, ast.Name)
                  and a.id in local_dicts
                  and "op" in local_dicts[a.id]):
                payload = local_dicts[a.id]
            if payload is None:
                continue
            op = payload.get("op")
            if op is None:
                continue  # dynamic op name: nothing to check
            out.append(SendSite(op=op, file=fn.file,
                                function=fn.qualname, line=n.lineno,
                                keys=set(payload)))
    return out
