from repro.models.registry import Model, build_model, input_specs, cache_specs, make_batch, shape_window

__all__ = ["Model", "build_model", "input_specs", "cache_specs", "make_batch",
           "shape_window"]
