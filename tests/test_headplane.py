"""Sharded + batched head control plane: equivalence, clock, accounting.

Four families of checks back the sharded-head PR (tests/README.md,
"Sharded head protocol"):

  1. clock skew: the HybridClock anchors wall time once and advances it
     monotonically, so an NTP step mid-transfer can neither expire every
     in-flight ticket (a relay-fallback storm) nor reject fresh sealed
     envelopes as stale,
  2. retry accounting: transfer/link counters are attempt-idempotent --
     a flaky transport's retry charges one blob's bytes exactly once,
  3. equivalence: property tests drive the SAME random op interleavings
     through shards=1 (the seed-exact baseline) and shards=N twins and
     require identical directories, decisions, and stats -- plus a chaos
     case (one ready shard hot while a worker drains) holding the global
     storage invariants of tests/_invariants.py,
  4. wire batching: the `batch` frame's replies align 1:1 with its
     sub-ops, nested batches are refused, metric deltas fold into the
     head's aggregate, and a batched `tickets` re-mint returns per-dep
     verdicts so one expired dep cannot poison the rest.

Runs under real `hypothesis` when installed, else the deterministic
fallback shim (tests/_hypothesis_fallback.py).
"""
import time
from collections import deque

import pytest

from repro.core import (ObjectRef, Scheduler, SchedulerConfig, SimCluster,
                        SimCostModel, SyndeoCluster, TaskSpec, WorkerInfo)
from repro.core.object_store import (GlobalObjectStore, InProcessTransport,
                                     NodeStore)
from repro.core.security import (HybridClock, SecurityError, TransferTicket,
                                 open_sealed, seal, set_clock)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from _invariants import check_invariants


def _noop():
    return None


# ------------------------------------------------------------ clock skew


class _FakeClock:
    def __init__(self, t: float):
        self.t = t

    def now(self) -> float:
        return self.t


def test_hybrid_clock_is_immune_to_wall_steps(monkeypatch):
    """A wall-clock step after construction does not move an existing
    HybridClock; a clock constructed after the step anchors at the new
    wall time (wire timestamps stay unix-comparable across hosts)."""
    base = time.time()
    clk = HybridClock()
    before = clk.now()
    monkeypatch.setattr(time, "time", lambda: base + 60.0)
    after = clk.now()
    assert abs(after - before) < 1.0, \
        f"wall step leaked into HybridClock: {after - before:+.1f}s"
    stepped = HybridClock()
    assert stepped.now() - after > 55.0, \
        "a freshly anchored clock must see the stepped wall time"


def test_ticket_survives_wall_step_but_still_expires(monkeypatch):
    """±60s NTP steps mid-window leave a 30s ticket valid; step-immune
    time still enforces the real expiry."""
    token = "tok"
    t = TransferTicket.grant(token, "o1", "a", "b", ttl_s=30.0)
    base = time.time()
    for step in (+60.0, -60.0):
        monkeypatch.setattr(time, "time", lambda s=step: base + s)
        t.verify(token, "o1", "a", "b")        # must not raise
    monkeypatch.undo()
    with pytest.raises(SecurityError, match="expired"):
        t.verify(token, "o1", "a", "b", now=t.expires_at + 1.0)


def test_sealed_envelope_freshness_survives_wall_step(monkeypatch):
    """A +60s step would instantly stale every envelope under a 5s replay
    window if freshness math read the wall clock; the hybrid clock keeps
    the envelope fresh through steps in both directions."""
    env = seal("tok", {"x": 1})
    base = time.time()
    for step in (+60.0, -60.0):
        monkeypatch.setattr(time, "time", lambda s=step: base + s)
        assert open_sealed("tok", env, max_age_s=5.0) == {"x": 1}


def test_injected_clock_drives_mint_and_expiry():
    """set_clock() threads a test clock through mint AND verify: expiry
    is decided by the injected time base, not the host's."""
    prev = set_clock(_FakeClock(1000.0))
    try:
        t = TransferTicket.grant("tok", "o", "a", "b", ttl_s=30.0)
        assert t.expires_at == pytest.approx(1030.0)
        t.verify("tok", "o", "a", "b")
        set_clock(_FakeClock(1030.5))
        with pytest.raises(SecurityError, match="expired"):
            t.verify("tok", "o", "a", "b")
    finally:
        set_clock(prev)


def test_wall_step_mid_transfer_no_ticket_reject_no_fallback(monkeypatch):
    """Regression for the clock-skew bug: jump the wall clock BETWEEN
    ticket mint and the guarded fetch. The fetch must complete on the
    first attempt -- zero ticket_rejects, zero relay_fallbacks."""
    store = GlobalObjectStore()
    for n in ("a", "b"):
        store.register_node(NodeStore(n))
    store.set_access_guard("cluster-token")
    store.set_transfer_guard()
    ref = store.put("a", b"payload" * 100)
    for step in (+60.0, -60.0):
        ticket = store.grant_fetch(ref, "b", "default", ttl_s=30.0)
        assert ticket is not None
        base = time.time()
        monkeypatch.setattr(time, "time", lambda s=step: base + s)
        moved = store.fetch("b", ref, ticket=ticket)
        monkeypatch.undo()
        assert moved > 0 or store.locations(ref) >= {"a", "b"}
        assert store.stats["ticket_rejects"] == 0
        assert store.stats["relay_fallbacks"] == 0
        # reset for the second direction
        store.release(ref)
        ref = store.put("a", b"payload" * 100)


# ------------------------------------------------------ retry accounting


class _FlakyTransport(InProcessTransport):
    """Drops the first fetch attempt on the floor (connection reset)."""

    def __init__(self, fail_first: int = 1):
        self.calls = 0
        self.fail_first = fail_first

    def fetch(self, src_store, ref, ticket=None):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise OSError("simulated transport reset")
        return super().fetch(src_store, ref, ticket)


def test_retried_fetch_charges_link_accounting_once():
    """Regression for the retry-accounting bug: a failed attempt charges
    nothing, the successful retry charges exactly one blob, and a
    duplicate retry after landing is a free no-op."""
    store = GlobalObjectStore(transport=_FlakyTransport())
    for n in ("a", "b"):
        store.register_node(NodeStore(n))
    ref = store.put("a", b"x" * 1000)
    with pytest.raises(OSError):
        store.fetch("b", ref)
    assert store.stats["transfers"] == 0
    assert store.stats["transfer_bytes"] == 0
    assert store.link_load("a") == 0 and store.link_load("b") == 0

    moved = store.fetch("b", ref)              # the worker's retry
    assert moved == ref.size
    assert store.stats["transfers"] == 1
    assert store.stats["transfer_bytes"] == ref.size
    assert store.link_load("a") == ref.size
    assert store.link_load("b") == ref.size

    assert store.fetch("b", ref) == 0          # over-eager duplicate retry
    assert store.stats["transfers"] == 1
    assert store.stats["transfer_bytes"] == ref.size
    assert store.link_load("a") == ref.size


def test_import_blob_reports_duplicate_copy():
    """The landing side of the same bug: a node already holding the blob
    reports the import as a duplicate so receive counters stay exact."""
    ns = NodeStore("n")
    ref = ObjectRef("dup-1", 4)
    assert ns.import_blob(ref, b"abcd") is True
    assert ns.import_blob(ref, b"abcd") is False


# ------------------------------------------- sharded == single-shard


def _mirrored_stores():
    stores = []
    for shards in (1, 8):
        s = GlobalObjectStore(shards=shards)
        for n in ("a", "b", "c"):
            s.register_node(NodeStore(n))
        stores.append(s)
    return stores


@settings(max_examples=25)
@given(st.lists(st.integers(min_value=0, max_value=9999),
                min_size=1, max_size=60))
def test_sharded_directory_equals_single_shard(codes):
    """Property: the SAME random put/fetch/add_ref/release interleaving
    through shards=1 and shards=8 yields identical outcomes (including
    exceptions), directories, and transfer stats."""
    stores = _mirrored_stores()
    nodes = ("a", "b", "c")
    live = []
    for k, code in enumerate(codes):
        action = code % 4
        outcomes = []
        for s in stores:
            try:
                if action == 0:
                    s.put(nodes[code % 3], b"v" * (1 + code % 7),
                          ref_id=f"o{k}")
                    outcome = ("put", f"o{k}")
                elif action == 1 and live:
                    oid = live[code % len(live)]
                    moved = s.fetch(nodes[(code // 4) % 3], ObjectRef(oid))
                    outcome = ("fetch", oid, moved)
                elif action == 2 and live:
                    oid = live[code % len(live)]
                    s.add_ref(ObjectRef(oid))
                    outcome = ("add_ref", oid)
                elif action == 3 and live:
                    oid = live[code % len(live)]
                    s.release(ObjectRef(oid))
                    outcome = ("release", oid)
                else:
                    outcome = ("noop",)
            except Exception as e:  # noqa: BLE001 -- mirrored verdicts
                outcome = ("raise", type(e).__name__)
            outcomes.append(outcome)
        assert outcomes[0] == outcomes[1], \
            f"op {k} diverged: {outcomes[0]} vs {outcomes[1]}"
        if action == 0:
            live.append(f"o{k}")
    dirs = [s.directory_snapshot()[0] for s in stores]
    assert dirs[0] == dirs[1]
    for key in ("transfers", "transfer_bytes", "records"):
        assert stores[0].stats[key] == stores[1].stats[key], key


def _twin_scheduler(shards):
    log = []
    store = GlobalObjectStore(shards=shards)
    cfg = SchedulerConfig(shards=shards, enable_speculation=False,
                          heartbeat_timeout=1e9)
    sched = Scheduler(store, lambda t, w: log.append((t.id, t.spec.name)),
                      lambda t, w: None, cfg)
    for i in range(4):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    return sched, log


@settings(max_examples=15)
@given(st.lists(st.integers(min_value=0, max_value=9999),
                min_size=1, max_size=80))
def test_sharded_scheduler_matches_single_shard_decisions(codes):
    """Property: random submit/finish/fail interleavings across tenants
    produce the SAME launch sequence (by task name) and the same
    launched/finished/failed/retried counters on shards=1 and shards=8."""
    twins = [_twin_scheduler(1), _twin_scheduler(8)]
    cursor = [0, 0]                 # next launched-but-unsettled task
    n_submitted = 0

    def names(j):
        return [name for _, name in twins[j][1]]

    for code in codes:
        act = code % 3
        if act == 0:
            for sched, _ in twins:
                sched.submit(TaskSpec(fn=_noop, name=f"t{n_submitted}",
                                      tenant_id=f"ten{code % 3}"))
            n_submitted += 1
        elif cursor[0] < len(twins[0][1]):
            for j, (sched, log) in enumerate(twins):
                tid, _ = log[cursor[j]]
                if act == 1:
                    sched.on_task_finished(tid, ObjectRef(f"obj-{tid}"))
                else:
                    sched.on_task_failed(tid, "chaos: injected failure")
                cursor[j] += 1
        assert names(0) == names(1), "launch decisions diverged mid-stream"
    while cursor[0] < len(twins[0][1]):     # settle the backlog
        for j, (sched, log) in enumerate(twins):
            tid, _ = log[cursor[j]]
            sched.on_task_finished(tid, ObjectRef(f"obj-{tid}"))
            cursor[j] += 1
    assert names(0) == names(1)
    for key in ("launched", "finished", "failed", "retried"):
        assert twins[0][0].stats[key] == twins[1][0].stats[key], key


def test_chaos_hot_shard_while_another_drains():
    """Chaos case from the issue: one tenant floods its ready shard while
    a worker holding live results drains. Every task must still finish,
    the drained node must leave the cluster, and the global storage
    invariants (tests/_invariants.py) must hold on the sharded store."""
    cost = SimCostModel(task_time_s=lambda s: 0.05,
                        result_bytes=lambda s: 4096.0, jitter=0.0,
                        result_location="worker", data_plane="p2p")
    sim = SimCluster(cost, SchedulerConfig(shards=4,
                                           enable_speculation=False,
                                           heartbeat_timeout=1e9))
    ids = sim.add_workers(6)
    tasks = [sim.submit(TaskSpec(fn=_noop, name=f"hot{i}", tenant_id="hot"))
             for i in range(48)]
    tasks += [sim.submit(TaskSpec(fn=_noop, name=f"cold{i}",
                                  tenant_id=f"cold{i % 2}"))
              for i in range(6)]
    victim = ids[0]
    sim.drain_worker_at(victim, 0.2)
    sim.run()
    for t in tasks:
        cur = sim.scheduler.graph.tasks[t.id]
        assert cur.output is not None, f"{cur.spec.name} never finished"
    assert victim not in sim.scheduler.workers, "drained worker lingered"
    snapshot = check_invariants(sim.store, scheduler=sim.scheduler)
    for oid, (locs, _, _) in snapshot.items():
        assert victim not in locs, f"{oid} still lists the drained node"


# ------------------------------------------------------- wire batching


def test_batch_frame_replies_align_and_refuse_nesting():
    """One `batch` frame: replies align 1:1 with sub-ops, the piggybacked
    result_meta lands the result, metric deltas fold into the `metrics`
    aggregate, and a nested batch gets a per-sub refusal -- all without
    failing the frame."""
    from repro.core.worker import HeadServer

    cluster = SyndeoCluster(scheduler_config=SchedulerConfig(
        shards=4, enable_speculation=False, heartbeat_timeout=1e9))
    server = HeadServer(cluster)
    server.attach()
    try:
        server.dispatch({"op": "join", "worker": "tcp-b",
                         "resources": {"cpu": 1.0}})
        task = cluster.submit(pow, 2, 10, tenant_id="alice")
        got = server.dispatch({"op": "poll", "worker": "tcp-b"})
        assert got["task"] == task.id
        reply = server.dispatch({"op": "batch", "worker": "tcp-b", "ops": [
            {"op": "result_meta", "task": task.id, "worker": "tcp-b",
             "size": 64},
            {"op": "metric_deltas", "worker": "tcp-b",
             "deltas": {"serves": 3, "served_bytes": 4096}},
            {"op": "batch", "worker": "tcp-b", "ops": []},
            {"op": "poll", "worker": "tcp-b"},
        ]})
        assert reply["ok"] and len(reply["replies"]) == 4
        meta_r, metric_r, nested_r, poll_r = reply["replies"]
        assert meta_r["ok"] and meta_r["stored"]
        assert metric_r["ok"]
        assert not nested_r["ok"] and "nested" in nested_r["error"]
        assert poll_r["ok"] and poll_r["task"] is None   # queue is empty
        cur = cluster.scheduler.graph.tasks[task.id]
        assert cur.output is not None, "batched result_meta must finish it"
        metrics = server.dispatch({"op": "metrics"})
        assert metrics["syndeo_worker_blob_serves"] == 3
        assert metrics["syndeo_worker_served_bytes"] == 4096
    finally:
        server.shutdown()
        cluster.shutdown()


def test_batch_bad_sub_op_gets_verdict_not_frame_failure():
    """A malformed sub-op yields {"ok": False, "error": ...} in ITS slot;
    the neighbors still execute."""
    from repro.core.worker import HeadServer

    cluster = SyndeoCluster(scheduler_config=SchedulerConfig(
        shards=2, enable_speculation=False, heartbeat_timeout=1e9))
    server = HeadServer(cluster)
    server.attach()
    try:
        server.dispatch({"op": "join", "worker": "tcp-c",
                         "resources": {"cpu": 1.0}})
        reply = server.dispatch({"op": "batch", "worker": "tcp-c", "ops": [
            {"op": "error", "worker": "tcp-c"},          # missing "task"
            {"op": "poll", "worker": "tcp-c"},
        ]})
        assert reply["ok"] and len(reply["replies"]) == 2
        bad, good = reply["replies"]
        assert not bad["ok"] and "KeyError" in bad["error"]
        assert good["ok"]
    finally:
        server.shutdown()
        cluster.shutdown()


def test_batched_tickets_partial_denial_per_dep_verdicts():
    """The batched `tickets` re-mint: a denied dep (cross-tenant) gets
    its own {"ok": False} verdict while the valid dep in the same frame
    is re-minted -- one bad dep never fails the whole batch. A dep with
    no live copies stays ok=True with empty sources (the worker reports
    the miss; a ticket complaint would mask it)."""
    from repro.core.worker import HeadServer

    cluster = SyndeoCluster(scheduler_config=SchedulerConfig(
        shards=2, enable_speculation=False, heartbeat_timeout=1e9))
    server = HeadServer(cluster)
    server.attach()
    try:
        server.dispatch({"op": "join", "worker": "tcp-d",
                         "resources": {"cpu": 1.0}})
        dep = cluster.put({"d": 1}, tenant_id="alice")
        secret = cluster.put({"s": 1}, tenant_id="bob")
        task = cluster.submit(lambda x: x, deps=[dep], tenant_id="alice")
        reply = server.dispatch({"op": "tickets", "worker": "tcp-d",
                                 "task": task.id,
                                 "objects": [dep.id, secret.id,
                                             "obj-never-existed"]})
        assert reply["ok"] and len(reply["deps"]) == 3
        good, denied, missing = reply["deps"]
        assert good["ok"] and good["dep"]["ref"] == dep.id
        assert not denied["ok"] and "SecurityError" in denied["error"]
        assert missing["ok"] and missing["dep"]["sources"] == []
        unknown = server.dispatch({"op": "tickets", "worker": "tcp-d",
                                   "task": "no-such-task",
                                   "objects": [dep.id]})
        assert not unknown["ok"]
    finally:
        server.shutdown()
        cluster.shutdown()


def test_headplane_decision_stream_smoke():
    """Miniature of the benchmark gate: a steady-state arrival stream on
    shards=8 launches and finishes every task (the CI perf gate itself
    lives in benchmarks/dataplane_bench.py --headplane-smoke)."""
    store = GlobalObjectStore(shards=8)
    cfg = SchedulerConfig(shards=8, enable_speculation=False,
                          heartbeat_timeout=1e9)
    launched = deque()
    sched = Scheduler(store, lambda t, w: launched.append(t.id),
                      lambda t, w: None, cfg)
    for i in range(16):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    total, submitted, finished = 200, 0, 0
    while submitted < 32:
        sched.submit(TaskSpec(fn=_noop, name=f"t{submitted}",
                              tenant_id=f"ten{submitted % 4}"))
        submitted += 1
    while finished < total and launched:
        tid = launched.popleft()
        sched.on_task_finished(tid, ObjectRef(f"obj-{tid}"))
        finished += 1
        if submitted < total:
            sched.submit(TaskSpec(fn=_noop, name=f"t{submitted}",
                                  tenant_id=f"ten{submitted % 4}"))
            submitted += 1
    assert finished == total
    assert sched.stats["launched"] == total
    assert sched.stats["finished"] == total
