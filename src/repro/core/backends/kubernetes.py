"""Kubernetes backend (paper §III-E: cloud deployment).

Renders a head Service + head Pod + worker Deployment running the same
Apptainer image (via the sif->OCI bridge or directly as an OCI image). The
rendezvous is a ConfigMap-backed shared mount -- same write-then-poll
protocol as the Slurm shared filesystem."""
from __future__ import annotations

from typing import Dict, List

from repro.core.backends.base import AllocationRequest, Backend


class KubernetesBackend(Backend):
    name = "kubernetes"
    supports_elastic = True

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        image = self.container.image.replace(".sif", ":latest")
        manifest = f"""\
apiVersion: v1
kind: Service
metadata:
  name: syndeo-head-{cluster_id}
spec:
  selector:
    app: syndeo-{cluster_id}
    role: head
  ports:
  - port: 6379
---
apiVersion: v1
kind: Pod
metadata:
  name: syndeo-head-{cluster_id}
  labels: {{app: syndeo-{cluster_id}, role: head}}
spec:
  securityContext:
    runAsNonRoot: true            # the Apptainer principle, K8s-enforced
    runAsUser: 1000
  containers:
  - name: head
    image: {image}
    command: ["{self.container.entrypoint.split()[0]}"]
    args: ["-m", "repro.core.worker", "--role", "head",
           "--rendezvous", "{req.shared_dir}", "--cluster-id", "{cluster_id}"]
    resources:
      requests: {{cpu: "{req.cpus_per_node}"}}
    volumeMounts:
    - name: rdv
      mountPath: {req.shared_dir}
  volumes:
  - name: rdv
    persistentVolumeClaim: {{claimName: syndeo-shared}}
---
apiVersion: apps/v1
kind: Deployment
metadata:
  name: syndeo-workers-{cluster_id}
spec:
  replicas: {req.nodes - 1}
  selector:
    matchLabels: {{app: syndeo-{cluster_id}, role: worker}}
  template:
    metadata:
      labels: {{app: syndeo-{cluster_id}, role: worker}}
    spec:
      securityContext:
        runAsNonRoot: true
        runAsUser: 1000
      containers:
      - name: worker
        image: {image}
        command: ["{self.container.entrypoint.split()[0]}"]
        args: ["-m", "repro.core.worker", "--role", "worker",
               "--rendezvous", "{req.shared_dir}", "--cluster-id", "{cluster_id}"]
        resources:
          requests: {{cpu: "{req.cpus_per_node}"}}
        volumeMounts:
        - name: rdv
          mountPath: {req.shared_dir}
      volumes:
      - name: rdv
        persistentVolumeClaim: {{claimName: syndeo-shared}}
"""
        return {f"syndeo_{cluster_id}.yaml": manifest}

    # -- elasticity: resize the worker Deployment ------------------------------

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        deploy = f"syndeo-workers-{cluster_id}"
        script = f"""\
#!/bin/bash
set -euo pipefail
# elastic scale-up: grow the worker Deployment by {count} replicas; new pods
# join the live head through the shared rendezvous volume.
CUR=$(kubectl get deployment {deploy} -o jsonpath='{{.spec.replicas}}')
kubectl scale deployment {deploy} --replicas=$((CUR + {count}))
"""
        return {f"scale_up_{cluster_id}_{count}.sh": script}

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        drain_deadline_s: float = 0.0) -> Dict[str, str]:
        deploy = f"syndeo-workers-{cluster_id}"
        # worker id == pod hostname == pod name in this backend (the worker
        # process registers under its hostname)
        annotates = "\n".join(
            f"kubectl annotate pod {wid} "
            f"controller.kubernetes.io/pod-deletion-cost=-999 "
            f"--overwrite || true"
            for wid in worker_ids)
        grace = int(drain_deadline_s) if drain_deadline_s > 0 else 0
        script = f"""\
#!/bin/bash
set -euo pipefail
# graceful scale-down: the scheduler already drained these pods (no new
# placements, hot objects migrated). Mark them cheapest to delete, then
# shrink the Deployment -- the ReplicaSet controller removes exactly those
# pods, each with a {grace}s termination grace for anything still exiting.
{annotates}
CUR=$(kubectl get deployment {deploy} -o jsonpath='{{.spec.replicas}}')
kubectl scale deployment {deploy} --replicas=$((CUR - {len(worker_ids)}))
kubectl wait --for=delete {' '.join(f'pod/{wid}' for wid in worker_ids)} \\
  --timeout={grace if grace > 0 else 30}s || true
"""
        return {f"scale_down_{cluster_id}.sh": script}
