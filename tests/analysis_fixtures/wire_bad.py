"""known-bad: op-frame drift between client and handler
(SYN-W001, SYN-W002, SYN-W003)."""


class Server:
    def __init__(self, store):
        self.store = store

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "store":
            value = msg["payload"]            # required, never sent
            return {"stored": bool(value)}    # reply lacks ok/error
        if op == "fetch":
            return {"ok": True, "value": msg.get("key")}
        return {"ok": False, "error": f"bad op {op}"}


def _request(host, port, token, msg):
    raise NotImplementedError


def client_store():
    return _request("h", 1, "t", {"op": "store", "key": "k"})


def client_flush():
    return _request("h", 1, "t", {"op": "flush"})   # no handler
