"""Fixture: metric-delta frames drifting from the head's aggregation.

Two observability-plane bugs the delta-field pass must catch:
* both client sites ship a ``hists`` payload the handler never folds
  -- an exported-but-never-aggregated metric (SYN-W001 on the
  pseudo-op ``metric_deltas#hists``, once per send site: the exit
  flush AND the queued batch sub-op),
* the handler requires a ``node`` envelope field no client site ever
  sends (SYN-W002).
"""


class Head:
    def __init__(self):
        self.agg = {}
        self.shard = None

    def _fold(self, msg):
        agg = self.agg.setdefault(msg.get("worker", ""), {})
        for k, v in (msg.get("deltas") or {}).items():
            agg[k] = agg.get(k, 0) + int(v)
        return {"ok": True}

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "metric_deltas":
            self.shard = msg["node"]
            return self._fold(msg)
        if op == "batch":
            return {"ok": True,
                    "replies": [self.dispatch(s)
                                for s in msg.get("ops") or []]}
        return {"ok": False, "error": f"unknown op {op!r}"}


def _request(host, port, token, msg):
    raise NotImplementedError


def flush(host, port, token, wid, deltas, hist):
    msg = {"op": "metric_deltas", "worker": wid, "deltas": deltas}
    if hist:
        msg["hists"] = {"poll_seconds": hist}
    return _request(host, port, token, msg)


def poll(host, port, token, wid, deltas, hist, ops):
    sub = {"op": "metric_deltas", "worker": wid, "deltas": deltas,
           "hists": {"poll_seconds": hist}}
    ops.append(sub)
    return _request(host, port, token,
                    {"op": "batch", "worker": wid, "ops": ops})
