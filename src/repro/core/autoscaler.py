"""Elastic autoscaler for the Syndeo runtime.

The paper's deployment model is a *static* gang allocation (Slurm job, K8s
deployment, TPU queued resources) hosting a *dynamic* scheduler. This module
closes the elasticity gap: it watches pending-task demand and worker
utilization on the inner scheduler and asks the backend to grow or shrink
the outer allocation through the `provision_workers` / `release_workers`
hooks (`core/backends/base.py`).

Policies (all active at once; the largest scale-up request wins):

  * queue depth   -- backlog of READY-but-unplaced tasks per worker,
  * target utilization -- keep busy-fraction near `target_utilization`,
  * gang demand   -- placement groups parked as pending (unsatisfiable)
                     request enough workers up front (STRICT_SPREAD needs
                     distinct workers, so bundles = workers).

Scale-down selects only *idle* workers (no running tasks, full resource
availability, not bound in a placement group) that have been idle longer
than `idle_timeout_s`, and never below `min_workers`. Both directions have
independent cooldowns so the cluster doesn't flap.

Retirement is a **drain, not a drop**: a victim first enters the
scheduler's DRAINING state (`begin_drain`), which stops new placements and
migrates the node's solely-held hot objects to survivors; only once
`drain_complete` does the autoscaler `finish_drain` and hand the worker
ids to `release_fn` (the backend's release artifact). If demand returns
while drains are in flight, the drains are cancelled and the workers
resume serving -- cheaper than re-provisioning. `release_order` chooses
which ripe workers go first: "idle" (longest-idle, the default) or
"reverse_join" (most-recently-joined -- GCP TPU slices, where pod 0 holds
the jax.distributed coordinator and early ranks must stay stable).

Multi-tenancy: scale-up reacts to *aggregate* backlog (attributed per
tenant in the event reason), while scale-down respects per-tenant
minimum-worker floors (`tenant_min_workers`) for every admitted tenant --
see `effective_min_workers`.

Cooldowns are backend-specific: `AutoscalerConfig.for_backend("gcp_tpu")`
uses minutes-scale cooldowns (queued-resource creation latency is minutes),
while "local"/"sim" default to seconds.

The autoscaler is time-source agnostic like the scheduler: the threaded
backend ticks it from the head's health loop with the wall clock, the
simulation backend ticks it with the virtual clock.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.scheduler import Scheduler
from repro.core.task_graph import TaskState


@dataclass
class AutoscalerConfig:
    min_workers: int = 0
    max_workers: int = 64
    worker_resources: Dict[str, float] = field(
        default_factory=lambda: {"cpu": 1.0})
    # scale-up policy
    queue_depth_per_worker: float = 2.0   # tolerated READY backlog per worker
    target_utilization: float = 0.75      # desired busy-worker fraction
    scale_up_cooldown_s: float = 1.0
    max_scale_up_step: int = 16           # workers added per decision, max
    # scale-down policy
    idle_timeout_s: float = 10.0          # idle this long before eligible
    scale_down_cooldown_s: float = 30.0
    max_scale_down_step: int = 8
    # drain-before-release policy
    drain_deadline_s: Optional[float] = None  # preempt stragglers after this
    release_order: str = "idle"           # "idle" | "reverse_join"
    # multi-tenancy: scale-up is driven by *aggregate* demand (backlog is
    # attributed per tenant for observability), but scale-down never shrinks
    # the pool below the sum of the minimums of admitted tenants -- a bursty
    # neighbor going quiet cannot starve a steady tenant's floor away
    # between its arrivals (see effective_min_workers).
    tenant_min_workers: Dict[str, int] = field(default_factory=dict)

    #: per-backend cooldown/drain defaults (see for_backend). GCP TPU
    #: queued-resource creation latency is minutes, so its cooldowns are
    #: minutes-scale; the in-process local/sim backends react in seconds.
    BACKEND_DEFAULTS = {
        "local": dict(scale_up_cooldown_s=1.0, scale_down_cooldown_s=30.0,
                      idle_timeout_s=10.0, drain_deadline_s=5.0),
        "sim": dict(scale_up_cooldown_s=1.0, scale_down_cooldown_s=30.0,
                    idle_timeout_s=10.0, drain_deadline_s=5.0),
        "slurm": dict(scale_up_cooldown_s=30.0, scale_down_cooldown_s=120.0,
                      idle_timeout_s=60.0, drain_deadline_s=60.0),
        "kubernetes": dict(scale_up_cooldown_s=15.0,
                           scale_down_cooldown_s=60.0,
                           idle_timeout_s=30.0, drain_deadline_s=30.0),
        "gcp_tpu": dict(scale_up_cooldown_s=180.0,
                        scale_down_cooldown_s=600.0,
                        idle_timeout_s=300.0, drain_deadline_s=120.0,
                        release_order="reverse_join"),
    }

    @classmethod
    def for_backend(cls, backend_name: str, **overrides) -> "AutoscalerConfig":
        """Config tuned for a backend's control-plane latency; keyword
        overrides win over the backend defaults."""
        defaults = dict(cls.BACKEND_DEFAULTS.get(backend_name, {}))
        defaults.update(overrides)
        return cls(**defaults)


@dataclass
class ScalingEvent:
    at: float
    action: str          # "scale_up" | "scale_down"
    count: int
    reason: str
    workers_before: int


class Autoscaler:
    """Policy engine. `provision_fn(count, resources)` asks the backend for
    `count` more workers (they join asynchronously; the backend must call
    `note_joined` for each so in-flight requests aren't double-counted).
    `release_fn(worker_ids)` retires idle workers."""

    def __init__(self, scheduler: Scheduler,
                 provision_fn: Callable[[int, Dict[str, float]], int],
                 release_fn: Callable[[List[str]], None],
                 config: Optional[AutoscalerConfig] = None,
                 clock: Callable[[], float] = None):
        self.scheduler = scheduler
        self.provision_fn = provision_fn
        self.release_fn = release_fn
        self.cfg = config or AutoscalerConfig()
        self.clock = clock or scheduler.clock
        self._pending_provision = 0
        self._last_up = -math.inf
        self._last_down = -math.inf
        self._idle_since: Dict[str, float] = {}
        self._draining: set = set()      # drains this autoscaler started
        self.events: List[ScalingEvent] = []

    # -- membership feedback --------------------------------------------------

    def note_joined(self, worker_id: str):
        self._pending_provision = max(0, self._pending_provision - 1)

    # -- observation ----------------------------------------------------------

    def _backlog(self) -> int:
        return sum(1 for t in self.scheduler.graph.tasks.values()
                   if t.state in (TaskState.READY, TaskState.PENDING))

    def effective_min_workers(self) -> int:
        """Scale-down floor: the global minimum, or the sum of per-tenant
        minimums over *admitted* tenants (registered with the scheduler) --
        whichever is larger. A steady tenant's floor holds between its
        arrivals: a bursty neighbor going quiet cannot trigger a shrink
        below capacity another tenant was promised."""
        tenant_floor = sum(n for t, n in self.cfg.tenant_min_workers.items()
                           if t in self.scheduler.tenants)
        return max(self.cfg.min_workers, tenant_floor)

    def _attribution(self) -> str:
        """Per-tenant backlog breakdown for multi-tenant scale-up reasons."""
        by = self.scheduler.backlog_by_tenant()
        if len(by) <= 1:
            return ""
        parts = ", ".join(f"{t}:{n}" for t, n in sorted(by.items()))
        return f" [{parts}]"

    def _gang_demand(self, n_live: int) -> int:
        """Workers needed to satisfy the largest parked placement group."""
        need = 0
        for bundles, strategy in \
                self.scheduler.pending_placement_groups().values():
            if strategy == "STRICT_SPREAD":
                need = max(need, len(bundles) - n_live)
            else:
                per_worker = sum(self.cfg.worker_resources.values()) or 1.0
                demand = sum(sum(b.values()) for b in bundles)
                need = max(need, math.ceil(demand / per_worker) - n_live)
        return max(0, need)

    def desired_delta(self) -> tuple:
        """(workers wanted beyond the live+in-flight pool, reason)."""
        workers = [w for w in self.scheduler.workers.values() if w.alive]
        n_live = len(workers) + self._pending_provision
        busy = sum(1 for w in workers if w.running)
        backlog = self._backlog()

        want = 0
        reason = ""
        if n_live == 0 and backlog > 0:
            # bootstrap: no pool at all, but work is queued
            want = max(1, math.ceil(backlog / self.cfg.queue_depth_per_worker))
            reason = f"bootstrap: {backlog} tasks, no workers"
        elif backlog > self.cfg.queue_depth_per_worker * max(n_live, 1):
            want = math.ceil(backlog / self.cfg.queue_depth_per_worker) - n_live
            reason = f"queue depth {backlog} over {n_live} workers"
        # utilization amplifies only when demand is actually queued --
        # otherwise a fully-busy pool with nothing waiting would provision
        # workers that sit idle until scale-down retires them (flapping)
        if workers and backlog > 0 \
                and busy / len(workers) > self.cfg.target_utilization:
            util_want = math.ceil(busy / self.cfg.target_utilization) - n_live
            if util_want > want:
                want, reason = util_want, \
                    f"utilization {busy}/{len(workers)} over target"
        gang = self._gang_demand(n_live)
        if gang > want:
            want, reason = gang, "pending placement group"
        if want > 0 and backlog > 0:
            # aggregate demand drives the scale-up; per-tenant attribution
            # rides along so operators see who is asking
            reason += self._attribution()
        return want, reason

    # -- the control loop body -------------------------------------------------

    def tick(self, now: Optional[float] = None) -> Optional[ScalingEvent]:
        now = self.clock() if now is None else now
        if self._draining:
            self.scheduler.check_drains(now)   # deadline preemption
        ev = self._maybe_scale_up(now)
        if ev is None:
            ev = self._maybe_scale_down(now)
        return ev

    def _maybe_scale_up(self, now: float) -> Optional[ScalingEvent]:
        want, reason = self.desired_delta()
        if want <= 0 or now - self._last_up < self.cfg.scale_up_cooldown_s:
            return None
        n_live = sum(1 for w in self.scheduler.workers.values() if w.alive) \
            + self._pending_provision
        count = min(want, self.cfg.max_scale_up_step,
                    self.cfg.max_workers - n_live)
        if count <= 0:
            return None
        # count the request as in-flight *before* calling the backend: a
        # synchronous backend (threaded local) invokes note_joined from
        # inside provision_fn, and that decrement must see the increment
        self._pending_provision += count
        granted = self.provision_fn(count, dict(self.cfg.worker_resources))
        shortfall = count - granted
        if shortfall:
            self._pending_provision = max(0,
                                          self._pending_provision - shortfall)
        if not granted:
            return None
        self._last_up = now
        ev = ScalingEvent(now, "scale_up", granted, reason, n_live)
        self.events.append(ev)
        return ev

    def _maybe_scale_down(self, now: float) -> Optional[ScalingEvent]:
        workers = {wid: w for wid, w in self.scheduler.workers.items()
                   if w.alive}
        # drains for workers that died mid-drain are moot
        self._draining &= set(workers)
        backlog = self._backlog()
        if backlog > 0 and self._draining:
            # demand returned: un-drain instead of re-provisioning
            for wid in list(self._draining):
                if self.scheduler.cancel_drain(wid):
                    self._draining.discard(wid)

        # phase 2 of earlier decisions: finish drains whose tasks are done
        # and whose migrations have landed (not gated by the cooldown --
        # the victim selection already was)
        released: List[str] = []
        for wid in list(self._draining):
            if self.scheduler.drain_complete(wid) \
                    and self.scheduler.finish_drain(wid):
                self._draining.discard(wid)
                released.append(wid)

        # refresh idle tracking. WorkerInfo.idle is already False for
        # actor hosts (a long-running replica is load, not idleness), so a
        # worker hosting service actors never accrues idle time here.
        for wid, w in workers.items():
            if w.idle:
                self._idle_since.setdefault(wid, now)
            else:
                self._idle_since.pop(wid, None)
        for wid in list(self._idle_since):
            if wid not in workers:
                del self._idle_since[wid]

        if backlog == 0 \
                and now - self._last_down >= self.cfg.scale_down_cooldown_s:
            n_live = len(workers) + self._pending_provision
            # workers already draining are as good as gone; the floor is
            # tenant-aware (active tenants keep their per-tenant minimums)
            headroom = (n_live - len(self._draining) - len(released)
                        - self.effective_min_workers())
            if headroom > 0:
                # actors_on re-checked at selection time: an actor placed
                # *after* the idle clock started must veto the candidacy
                # even before the next idle refresh sees w.idle flip
                ripe = [wid for wid, since in self._idle_since.items()
                        if now - since >= self.cfg.idle_timeout_s
                        and wid not in self._draining
                        and wid not in released
                        and not self.scheduler.actors_on(wid)]
                if self.cfg.release_order == "reverse_join":
                    ripe.sort(key=lambda wid:
                              -self.scheduler.worker_seq(wid))
                else:
                    ripe.sort(key=lambda wid: self._idle_since[wid])
                victims = ripe[:min(headroom, self.cfg.max_scale_down_step)]
                for wid in victims:
                    if not self.scheduler.begin_drain(
                            wid, self.cfg.drain_deadline_s):
                        continue
                    # idle workers with nothing to migrate complete at once
                    if self.scheduler.drain_complete(wid) \
                            and self.scheduler.finish_drain(wid):
                        released.append(wid)
                    else:
                        self._draining.add(wid)

        if not released:
            return None
        for wid in released:
            self._idle_since.pop(wid, None)
        self.release_fn(released)
        self._last_down = now
        n_before = len(workers) + self._pending_provision
        ev = ScalingEvent(now, "scale_down", len(released),
                          f"drained after idle > {self.cfg.idle_timeout_s}s",
                          n_before)
        self.events.append(ev)
        return ev


@dataclass
class ReplicaScalingConfig:
    """SLO targets for the serving-plane replica autoscaler."""
    min_replicas: int = 1
    max_replicas: int = 8
    p99_target_ms: float = 500.0         # grow when p99 exceeds this
    queue_depth_target: float = 4.0      # grow when mean backlog exceeds this
    low_water_fraction: float = 0.4      # shrink when BOTH signals are under
                                         # fraction * target
    scale_up_cooldown_s: float = 1.0
    scale_down_cooldown_s: float = 10.0
    max_step: int = 2                    # replicas added/removed per decision


class ReplicaAutoscaler:
    """SLO-driven replica-set autoscaler for the serving plane.

    Where `Autoscaler` sizes the *worker pool* on task backlog, this
    sizes a *replica set* on serving SLOs: it grows when the router's p99
    latency or mean queue depth exceeds the target, and shrinks -- via
    the drain plane, so an evicted replica finishes its in-flight
    decodes -- only when BOTH signals sit below the low-water fraction
    of their targets.

    `grow_fn(count) -> int` spawns up to `count` replicas and returns how
    many it actually created (e.g. `SimCluster.add_replica` + router
    registration, or actor_create over the wire). `shrink_fn(count) ->
    int` retires up to `count` replicas gracefully (it should route
    through `Router.retire_replica` / the actor-exit drain handshake) and
    returns how many it actually removed. Both may under-deliver; the
    autoscaler only trusts the returned counts."""

    def __init__(self, router, grow_fn: Callable[[int], int],
                 shrink_fn: Callable[[int], int],
                 config: Optional[ReplicaScalingConfig] = None,
                 clock: Callable[[], float] = None):
        self.router = router
        self.grow_fn = grow_fn
        self.shrink_fn = shrink_fn
        self.cfg = config or ReplicaScalingConfig()
        self.clock = clock or router.clock
        self._last_up = -math.inf
        self._last_down = -math.inf
        self.events: List[ScalingEvent] = []

    def _emit(self, now: float, action: str, count: int, reason: str,
              before: int) -> ScalingEvent:
        ev = ScalingEvent(now, action, count, reason, before)
        self.events.append(ev)
        return ev

    def tick(self, now: Optional[float] = None) -> Optional[ScalingEvent]:
        now = self.clock() if now is None else now
        n = len(self.router.replicas)
        p99 = self.router.p99_ms()
        depth = self.router.queue_depth()
        cfg = self.cfg

        over_p99 = p99 > cfg.p99_target_ms
        over_depth = depth > cfg.queue_depth_target
        if (over_p99 or over_depth) and n < cfg.max_replicas \
                and now - self._last_up >= cfg.scale_up_cooldown_s:
            want = min(cfg.max_step, cfg.max_replicas - n)
            got = self.grow_fn(want)
            if got > 0:
                self._last_up = now
                sig = (f"p99 {p99:.0f}ms > {cfg.p99_target_ms:.0f}ms"
                       if over_p99 else
                       f"queue depth {depth:.1f} > "
                       f"{cfg.queue_depth_target:.1f}")
                return self._emit(now, "scale_up", got, sig, n)
            return None

        under = (p99 <= cfg.p99_target_ms * cfg.low_water_fraction
                 and depth <= cfg.queue_depth_target * cfg.low_water_fraction)
        if under and n > cfg.min_replicas \
                and now - self._last_down >= cfg.scale_down_cooldown_s:
            want = min(cfg.max_step, n - cfg.min_replicas)
            got = self.shrink_fn(want)
            if got > 0:
                self._last_down = now
                return self._emit(
                    now, "scale_down", got,
                    f"p99 {p99:.0f}ms and depth {depth:.1f} under "
                    f"{cfg.low_water_fraction:.0%} of target", n)
        return None
