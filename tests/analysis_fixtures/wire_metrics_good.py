"""known-good: every metric-delta payload field the workers export has
a head-side fold, and the handler's envelope needs are all shipped --
the repaired twin of wire_metrics_bad.py."""


class Head:
    def __init__(self):
        self.agg = {}
        self.hists = {}

    def _fold(self, msg):
        agg = self.agg.setdefault(msg.get("worker", ""), {})
        for k, v in (msg.get("deltas") or {}).items():
            agg[k] = agg.get(k, 0) + int(v)
        for name, delta in (msg.get("hists") or {}).items():
            cur = self.hists.setdefault(name, {})
            for b, c in delta.items():
                cur[b] = cur.get(b, 0) + c
        return {"ok": True}

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "metric_deltas":
            return self._fold(msg)
        if op == "batch":
            return {"ok": True,
                    "replies": [self.dispatch(s)
                                for s in msg.get("ops") or []]}
        return {"ok": False, "error": f"unknown op {op!r}"}


def _request(host, port, token, msg):
    raise NotImplementedError


def flush(host, port, token, wid, deltas, hist):
    msg = {"op": "metric_deltas", "worker": wid, "deltas": deltas}
    if hist:
        msg["hists"] = {"poll_seconds": hist}
    return _request(host, port, token, msg)


def poll(host, port, token, wid, deltas, hist, ops):
    sub = {"op": "metric_deltas", "worker": wid, "deltas": deltas,
           "hists": {"poll_seconds": hist}}
    ops.append(sub)
    return _request(host, port, token,
                    {"op": "batch", "worker": wid, "ops": ops})
