"""zamba2-2.7b  [arXiv:2411.15242]
54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Mamba2 backbone + shared attention block every 6 layers. Sub-quadratic:
long_500k runs with a 4096-token sliding window on the attention layers."""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, chunk_size=256),
    hybrid=HybridConfig(attn_every=6, shared_attention=True),
    long_context_window=4096,
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk_size=32),
    hybrid=HybridConfig(attn_every=2, shared_attention=True),
    long_context_window=64,
    sub_quadratic=True,
)
