"""Slurm backend: renders the sbatch script that hosts a Syndeo cluster
inside a Slurm allocation (the paper's headline deployment).

The script implements the bring-up protocol exactly as §III-D describes:
node 0 starts the containerized head and writes IP:port to the shared
filesystem; every other node polls that file and joins as a worker."""
from __future__ import annotations

from typing import Dict, List

from repro.core.backends.base import AllocationRequest, Backend
from repro.core.containers import apptainer_definition, apptainer_run_command


class SlurmBackend(Backend):
    name = "slurm"
    supports_elastic = True

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        head_cmd = apptainer_run_command(self.container, role="head",
                                         rendezvous_dir=req.shared_dir,
                                         cluster_id=cluster_id)
        worker_cmd = apptainer_run_command(self.container, role="worker",
                                           rendezvous_dir=req.shared_dir,
                                           cluster_id=cluster_id)
        sbatch = f"""\
#!/bin/bash
#SBATCH --job-name=syndeo-{cluster_id}
#SBATCH --nodes={req.nodes}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={req.cpus_per_node}
#SBATCH --time={req.walltime}
#SBATCH --partition={req.partition}
#SBATCH --output={req.shared_dir}/logs/%j_%n.out

set -euo pipefail
mkdir -p {req.shared_dir}/logs {req.shared_dir}/rdv

# ---- phase 1: every node already has a copy of the container ----
# (image staged to {req.shared_dir} before submission; immutable at runtime)

NODELIST=$(scontrol show hostnames "$SLURM_JOB_NODELIST")
HEAD_NODE=$(echo "$NODELIST" | head -n1)

if [ "$(hostname)" = "$HEAD_NODE" ]; then
    # ---- phase 2: start the Ray-equivalent head; endpoint -> shared FS ----
    {head_cmd} &
    HEAD_PID=$!
else
    # ---- phase 3: workers poll the shared FS for the head endpoint ----
    {worker_cmd} &
    HEAD_PID=$!
fi

# ---- phase 4: the cluster accepts jobs at the head ----
wait $HEAD_PID
"""
        srun_variant = f"""\
#!/bin/bash
# Alternative launcher: one srun step per role (heterogeneous jobs).
srun --nodes=1 --ntasks=1 -w "$HEAD_NODE" {head_cmd} &
srun --nodes={req.nodes - 1} --ntasks={req.nodes - 1} {worker_cmd} &
wait
"""
        return {
            "syndeo.def": apptainer_definition(self.container),
            f"submit_{cluster_id}.sbatch": sbatch,
            f"srun_steps_{cluster_id}.sh": srun_variant,
        }

    # -- elasticity: a worker-only sbatch joins the live rendezvous ------------

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        worker_cmd = apptainer_run_command(self.container, role="worker",
                                           rendezvous_dir=req.shared_dir,
                                           cluster_id=cluster_id)
        scale_up = f"""\
#!/bin/bash
#SBATCH --job-name=syndeo-{cluster_id}-scaleup
#SBATCH --nodes={count}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={req.cpus_per_node}
#SBATCH --time={req.walltime}
#SBATCH --partition={req.partition}
#SBATCH --output={req.shared_dir}/logs/%j_%n.out

set -euo pipefail
# elastic scale-up: every node of this job joins the *existing* head via
# the shared-FS rendezvous (bring-up phase 3 only -- the head stays put).
{worker_cmd} &
wait
"""
        return {f"scale_up_{cluster_id}_{count}.sbatch": scale_up}

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str]) -> Dict[str, str]:
        drains = "\n".join(
            f"scontrol update NodeName={wid} State=DRAIN "
            f'Reason="syndeo-{cluster_id} idle scale-down"'
            for wid in worker_ids)
        nodelist = ",".join(worker_ids)
        scale_down = f"""\
#!/bin/bash
set -euo pipefail
# elastic scale-down: drain the retired nodes, then cancel only the
# scale-up jobs running *on those nodes* (workers there are idle by
# policy; scale-up batches on other nodes keep running).
{drains}
scancel --name=syndeo-{cluster_id}-scaleup --nodelist={nodelist} || true
"""
        return {f"scale_down_{cluster_id}.sh": scale_down}
