"""Serving plane: replica actors, the continuous-batching router, weight
broadcast, SLO autoscaling -- and the chaos scenarios that must end with
the global storage invariants intact (see tests/README.md, "Service actor
protocol"):

  * replica death mid-decode: its in-flight requests are re-routed, not
    lost, and re-decode to identical outputs (the engine is deterministic
    per prompt),
  * router death: replicas quiesce (finish what the dead router admitted)
    and re-register with a fresh router,
  * weight broadcast during scale-up: a replica joining mid-broadcast
    pulls from the nearest fresh replica; zero payload bytes cross the
    head link either way,
  * drain with in-flight requests: a retired replica finishes every
    admitted decode before it is released,
  * SLO autoscaler: ramping arrival grows the replica set, subsiding load
    drains it back down -- no dropped in-flight requests, invariants
    checked at every virtual tick.

Plus the property that routed execution over K replicas is
completion-equivalent to one local engine, and the satellite regressions:
actor hosts are excluded from idle-exit / idle scale-down, and preemption
notices drain with zero hot-producer re-execution.
"""
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container
    from _hypothesis_fallback import given, settings, strategies as st

from _invariants import check_invariants, check_metrics_conformance
from repro.core import SimCluster, SimCostModel, SyndeoCluster
from repro.core.autoscaler import (AutoscalerConfig, ReplicaAutoscaler,
                                   ReplicaScalingConfig)
from repro.core.rendezvous import FileRendezvous
from repro.core.worker import HeadServer, _dec, _enc, _request, run_worker
from repro.serve.engine import Request, StubEngine
from repro.serve.router import ActorReplicaHandle, ReplicaActor, Router


def _sim(n_workers=4, **cost_kw):
    cost = SimCostModel(task_time_s=lambda s: 0.05,
                        result_bytes=lambda s: 1024.0, jitter=0.0,
                        data_plane="p2p", result_location="worker",
                        **cost_kw)
    sim = SimCluster(cost)
    sim.add_workers(n_workers)
    return sim


def _reqs(n, tokens=6, offset=0):
    return [Request(id=offset + i, prompt=[offset + i, 17],
                    max_new_tokens=tokens) for i in range(n)]


def _expect(req):
    return StubEngine.stub_output(req.prompt, req.max_new_tokens)


# ------------------------------------------------ router admission basics


def test_router_fills_free_slots_before_queueing():
    r = Router(max_queue_per_replica=4)
    r.add_replica("r0", StubEngine(2))
    r.add_replica("r1", StubEngine(2))
    for q in _reqs(4):
        assert r.submit(q)
    # token-level admission: 4 requests over 2x2 slots -- both replicas
    # full, neither queueing while the other has a free slot
    assert all(h.free_slots == 0 for h in r.replicas.values())
    assert all(h.queue_len == 2 for h in r.replicas.values())


def test_router_sheds_to_retry_then_drops():
    r = Router(max_queue_per_replica=1, max_retry_backlog=2)
    r.add_replica("r0", StubEngine(1))
    accepted = [r.submit(q) for q in _reqs(8, tokens=4)]
    # 1 queue place (slot-bound request included), 2 park in retry, rest shed
    assert accepted.count(True) == 3
    assert r.stats["shed"] == 5
    done = r.flush()
    assert len(done) == 3            # retry buffer drained back in
    assert r.stats["retried"] >= 2


def test_routed_outputs_match_local_engine():
    reqs = _reqs(12, tokens=5)
    r = Router()
    for i in range(3):
        r.add_replica(f"r{i}", StubEngine(2))
    for q in reqs:
        assert r.submit(q)
    done = r.flush()
    assert sorted(q.id for q in done) == sorted(q.id for q in reqs)
    for q in reqs:
        assert q.done and q.output == _expect(q)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(1, 12), min_size=1, max_size=40),
       st.integers(1, 4), st.integers(1, 4))
def test_routed_execution_completion_equivalent(budgets, n_replicas, slots):
    """Property: routing a random request stream over K replicas completes
    exactly the same set of requests with exactly the same outputs as one
    local engine running the whole stream."""
    stream = [Request(id=i, prompt=[i % 7, len(budgets)], max_new_tokens=b)
              for i, b in enumerate(budgets)]
    local = StubEngine(slots)
    for q in stream:
        local.add_request(Request(id=q.id, prompt=list(q.prompt),
                                  max_new_tokens=q.max_new_tokens))
    reference = {q.id: q.output for q in local.run_until_drained(
        max_ticks=100000)}

    router = Router(max_queue_per_replica=3, max_retry_backlog=1000)
    for i in range(n_replicas):
        router.add_replica(f"r{i}", StubEngine(slots))
    for q in stream:
        assert router.submit(q)
    done = router.flush(max_ticks=100000)
    assert sorted(q.id for q in done) == sorted(reference)
    for q in done:
        assert q.output == reference[q.id]


# ------------------------------------------------------- chaos scenarios


def test_replica_death_mid_decode_rerouted_not_lost():
    sim = _sim(3)
    router = Router(clock=lambda: sim.now)
    for i in range(2):
        h = sim.add_replica(f"r{i}", batch_slots=2)
        router.add_replica(f"r{i}", h)
    reqs = _reqs(10, tokens=8)
    for q in reqs:
        assert router.submit(q)
    for _ in range(3):               # some decodes are genuinely mid-flight
        router.tick()
    victim = sim.replicas["r0"]
    assert any(len(router._inflight[rid]) for rid in router.replicas)
    sim.scheduler.on_worker_failed(victim.worker_id, reason="chaos")
    rerouted = router.fail_replica("r0")
    assert rerouted > 0
    done = router.flush()
    assert sorted(q.id for q in reqs) == sorted(q.id for q in done)
    for q in reqs:                   # re-decode reproduced identical tokens
        assert q.output == _expect(q)
    assert "r0" not in sim.scheduler.actors
    check_invariants(sim.store)
    check_metrics_conformance(sim.store, sim.scheduler, router=router)


def test_router_death_replicas_quiesce_and_reregister():
    sim = _sim(3)
    handles = {f"r{i}": sim.add_replica(f"r{i}", batch_slots=2)
               for i in range(2)}
    router = Router(clock=lambda: sim.now)
    for rid, h in handles.items():
        router.add_replica(rid, h)
    first = _reqs(8, tokens=6)
    for q in first:
        assert router.submit(q)
    for _ in range(2):
        router.tick()
    del router                        # the router process dies

    router2, recovered = Router.recover(dict(handles),
                                        clock=lambda: sim.now)
    # everything the dead router admitted into engines was finished by the
    # quiesce -- nothing is lost, outputs still correct
    for q in recovered:
        assert q.output == _expect(q)
    assert len(router2.replicas) == 2
    second = _reqs(6, tokens=4, offset=100)
    for q in second:
        assert router2.submit(q)
    done = router2.flush()
    assert {q.id for q in recovered} | {q.id for q in done} >= \
        {q.id for q in first}
    for q in second:
        assert q.output == _expect(q)
    check_invariants(sim.store)
    check_metrics_conformance(sim.store, sim.scheduler, router=router2)


def test_weight_broadcast_during_scale_up_zero_head_bytes():
    sim = _sim(5)
    weights = sim.store.put("w0", b"W" * 4096, ref_id="model-v1",
                            size_hint=64 << 20)
    joined = []

    def on_round(k):
        # scale-up lands MID-broadcast: the new replica pulls its weights
        # from the nearest fresh holder, not the producer or the head
        if k == 1 and not joined:
            h = sim.add_replica("r-late", batch_slots=2, weights=weights)
            joined.append(h)

    sim.store.broadcast(weights, ["w1", "w2", "w3"], on_round=on_round)
    assert joined and joined[0] is not None
    locs = sim.store.locations(weights)
    assert {"w0", "w1", "w2", "w3", joined[0].worker_id} <= locs
    assert sim.store.stats["head_relayed_bytes"] == 0
    assert joined[0].weights_version == weights.id
    # replica coherence across every landed copy + directory sanity
    check_invariants(sim.store, expect_fetchable=[weights.id])
    check_metrics_conformance(sim.store, sim.scheduler)


def test_drain_with_inflight_requests_completes_them():
    sim = _sim(3)
    router = Router(clock=lambda: sim.now)
    for i in range(2):
        router.add_replica(f"r{i}", sim.add_replica(f"r{i}", batch_slots=2))
    reqs = _reqs(9, tokens=7)
    for q in reqs:
        assert router.submit(q)
    for _ in range(2):
        router.tick()
    inflight_on_r0 = set(router._inflight["r0"])
    assert inflight_on_r0
    finished = router.retire_replica("r0")      # drain, not drop
    assert inflight_on_r0 <= {q.id for q in finished}
    sim.remove_replica("r0")
    assert "r0" not in sim.scheduler.actors
    done = router.flush()
    assert sorted(q.id for q in reqs) == sorted(
        q.id for q in finished + done)
    for q in reqs:
        assert q.output == _expect(q)
    check_invariants(sim.store)
    check_metrics_conformance(sim.store, sim.scheduler, router=router)


# ----------------------------------------------- SLO-driven autoscaling


def test_slo_autoscaler_grows_under_ramp_and_drains_when_quiet():
    sim = _sim(6)
    weights = sim.store.put("w5", b"W" * 2048, ref_id="model-v2",
                            size_hint=32 << 20)
    # a small p99 window: the quiet phase's fast completions must be able
    # to flush the burst-era samples out, or scale-down can never trigger
    router = Router(max_queue_per_replica=6, max_retry_backlog=4096,
                    p99_window=16, clock=lambda: sim.now)
    router.add_replica("r0", sim.add_replica("r0", batch_slots=4,
                                             weights=weights))
    next_id = [1]
    drained_out = []

    def grow(count):
        added = 0
        for _ in range(count):
            rid = f"r{next_id[0]}"
            h = sim.add_replica(rid, batch_slots=4, weights=weights)
            if h is None:
                break
            router.add_replica(rid, h)
            next_id[0] += 1
            added += 1
        return added

    def shrink(count):
        removed = 0
        # retire the most recently added first; never the last replica
        for rid in sorted(router.replicas, reverse=True)[:count]:
            if len(router.replicas) <= 1:
                break
            drained_out.extend(router.retire_replica(rid))
            sim.remove_replica(rid)
            removed += 1
        return removed

    ras = ReplicaAutoscaler(
        router, grow, shrink,
        ReplicaScalingConfig(min_replicas=1, max_replicas=4,
                             p99_target_ms=150.0, queue_depth_target=3.0,
                             low_water_fraction=0.5,
                             scale_up_cooldown_s=0.05,
                             scale_down_cooldown_s=0.4, max_step=2),
        clock=lambda: sim.now)

    # ramp: 140 requests at 200/s >> one replica's capacity, then quiet
    # trickle: 30 requests at 10/s << capacity
    arrivals = [(0.01 + 0.005 * i, q) for i, q in
                enumerate(_reqs(140, tokens=8))]
    arrivals += [(1.0 + 0.1 * i, q) for i, q in
                 enumerate(_reqs(30, tokens=4, offset=1000))]
    peak = [0]

    def on_tick(now):
        peak[0] = max(peak[0], len(router.replicas))
        check_invariants(sim.store)     # invariants hold THROUGHOUT

    completed = sim.run_serve(router, arrivals, tick_every=0.01,
                              drain_s=2.0, on_tick=on_tick,
                              replica_autoscaler=ras)
    all_done = completed + drained_out
    assert sorted(q.id for q in all_done) == sorted(
        q.id for _, q in arrivals)      # nothing dropped, ramp or drain
    for _, q in arrivals:
        assert q.output == _expect(q)
    assert peak[0] > 1, "ramp never grew the replica set"
    assert len(router.replicas) == 1, "quiet load did not drain replicas"
    assert any(e.action == "scale_up" for e in ras.events)
    assert any(e.action == "scale_down" for e in ras.events)
    assert sim.store.stats["head_relayed_bytes"] == 0   # weights were p2p
    check_invariants(sim.store, expect_fetchable=[weights.id])
    check_metrics_conformance(sim.store, sim.scheduler, router=router,
                              prom=sim.export_prometheus(router))


def test_replica_autoscaler_reacts_to_p99():
    r = Router(p99_window=16, clock=lambda: 100.0)
    r.add_replica("r0", StubEngine(2))
    r._latencies.extend([0.5] * 16)     # p99 = 500ms, target 150ms
    grown = []
    ras = ReplicaAutoscaler(r, lambda c: grown.append(c) or c,
                            lambda c: 0,
                            ReplicaScalingConfig(p99_target_ms=150.0,
                                                 queue_depth_target=100.0),
                            clock=lambda: 100.0)
    ev = ras.tick()
    assert ev is not None and ev.action == "scale_up" and grown
    assert "p99" in ev.reason


# --------------------------------- satellite: preemption-aware scale-down


def test_preempt_worker_drains_and_hands_off_before_deadline():
    sim = _sim(4)
    router = Router(clock=lambda: sim.now)
    h0 = sim.add_replica("r0", batch_slots=2)      # lands on w0 (least id)
    router.add_replica("r0", h0)
    victim_wid = h0.worker_id
    # hot objects solely held by the victim: the drain plane must migrate
    # them inside the notice window, never recompute them
    hot = [sim.store.put(victim_wid, {"shard": i}, ref_id=f"hot-{i}",
                         size_hint=1 << 20) for i in range(3)]
    reqs = _reqs(6, tokens=6)
    for q in reqs:
        assert router.submit(q)
    router.tick()                                   # decodes in flight

    sim.preempt_worker_at(victim_wid, t=0.5, notice_s=5.0, router=router)
    # run to well before the revocation deadline: the node must already
    # have drained gracefully (the deadline event then fires as a no-op)
    sim.run(until=2.0)
    assert victim_wid not in sim.scheduler.workers
    sim.run()
    assert sim.scheduler.stats["actors_lost"] == 0
    # the handoff's retire drained every in-flight decode on the way out
    # (no request dropped), and a successor serves on a survivor
    for q in reqs:
        assert q.done and q.output == _expect(q)
    assert list(router.replicas) == ["r0+"]
    assert router.replicas["r0+"].worker_id != victim_wid
    after = _reqs(3, tokens=4, offset=50)
    for q in after:
        assert router.submit(q)
    done = router.flush()
    assert sorted(q.id for q in done) == sorted(q.id for q in after)
    for q in after:
        assert q.output == _expect(q)
    # zero hot-producer re-execution: migration moved the bytes
    check_invariants(sim.store, expect_fetchable=[r.id for r in hot],
                     scheduler=sim.scheduler,
                     expect_zero_reconstructions=True)
    check_metrics_conformance(sim.store, sim.scheduler, router=router)


def test_preempt_past_deadline_falls_back_to_failure_path():
    sim = _sim(2)
    # a replica that is never handed off (no router) wedges the drain:
    # the revocation deadline must still reclaim the node
    h = sim.add_replica("r0", batch_slots=2)
    sim.preempt_worker_at(h.worker_id, t=0.1, notice_s=1.0)
    sim.run()
    assert h.worker_id not in sim.scheduler.workers
    assert sim.scheduler.stats["actors_lost"] == 1
    check_invariants(sim.store)
    check_metrics_conformance(sim.store, sim.scheduler)


# ------------------- satellite: actor hosts are excluded from idle paths


def test_idle_scale_down_skips_actor_hosts():
    sim = _sim(3)
    sim.attach_autoscaler(AutoscalerConfig(
        min_workers=0, max_workers=4, idle_timeout_s=0.5,
        scale_down_cooldown_s=0.1))
    sim.add_replica("r0", batch_slots=2)            # lands on w0
    host = sim.replicas["r0"].worker_id
    for t in (1.0, 2.0, 3.0, 4.0):
        sim._post(t - sim.now, lambda: None)
        sim.run()
        sim.autoscaler.tick(sim.now)
    # idle workers were drained away; the actor host NEVER became a victim
    assert host in sim.scheduler.workers
    others = [w for w in sim.scheduler.workers if w != host]
    assert not others, f"idle workers survived: {others}"
    check_invariants(sim.store)
    check_metrics_conformance(sim.store, sim.scheduler)


# ----------------------- real sockets: actor lifecycle + idle-exit guard


def test_socket_actor_keeps_worker_alive_past_idle_timeout(tmp_path):
    """Regression (satellite 1): a worker hosting a live replica actor
    must NOT start the idle-exit leave handshake, however long the gap
    between requests; after the actor exits, the idle clock resumes and
    the worker leaves normally. Also smoke-tests the full actor lifecycle
    over real sockets: create -> call -> result -> exit."""
    cluster = SyndeoCluster(rendezvous=FileRendezvous(str(tmp_path)))
    server = HeadServer(cluster)
    server.attach()
    t = threading.Thread(
        target=run_worker, args=(str(tmp_path), cluster.cluster_id, "sv-w0"),
        kwargs={"max_idle_s": 1.0,
                "actor_factories": {"replica": ReplicaActor}},
        daemon=True)
    t.start()
    try:
        deadline = time.time() + 20
        while time.time() < deadline and not any(
                w.alive for w in cluster.scheduler.workers.values()):
            time.sleep(0.05)
        host, port, token = "127.0.0.1", server.port, cluster.token

        made = _request(host, port, token,
                        {"op": "actor_create", "factory": "replica",
                         "actor": "rep0", "kwargs": {"batch_slots": 2}})
        assert made["ok"] and made["worker"] == "sv-w0"
        cap = made["cap"]

        def call(payload, timeout=10.0):
            sent = _request(host, port, token,
                            {"op": "actor_call", "actor": "rep0",
                             "cap": cap, "payload": _enc(payload)})
            assert sent["ok"]
            limit = time.time() + timeout
            while time.time() < limit:
                got = _request(host, port, token,
                               {"op": "actor_result", "call": sent["call"]})
                if got.get("done"):
                    assert "error" not in got or not got["error"], got
                    return _dec(got["value"])
                time.sleep(0.05)
            raise AssertionError("actor call never completed")

        handle = ActorReplicaHandle(call)
        router = Router()
        router.add_replica("rep0", handle)
        reqs = _reqs(3, tokens=4)
        for q in reqs:
            assert router.submit(q)
        done = router.flush(max_ticks=200)
        assert sorted(q.id for q in done) == sorted(q.id for q in reqs)
        for q in reqs:
            assert q.output == _expect(q)

        # idle gap far past max_idle_s with the actor still hosted: the
        # worker must stay (no leave handshake, no scale-down candidacy)
        time.sleep(2.5)
        w = cluster.scheduler.workers.get("sv-w0")
        assert w is not None and w.alive and "rep0" in w.actors

        # graceful exit releases the hold; NOW the idle clock runs again
        bye = _request(host, port, token,
                       {"op": "actor_exit", "actor": "rep0", "cap": cap})
        assert bye["ok"]
        deadline = time.time() + 20
        while time.time() < deadline and (
                "rep0" in cluster.scheduler.actors
                or "sv-w0" in cluster.scheduler.workers):
            time.sleep(0.1)
        assert "rep0" not in cluster.scheduler.actors
        assert "sv-w0" not in cluster.scheduler.workers
        t.join(timeout=10)
        assert not t.is_alive()
    finally:
        server.shutdown()
        cluster.shutdown()
