"""Syndeo core: the paper's contribution as a composable runtime.

Scheduler-inside-a-scheduler: a dynamic, dependency-driven head-worker
cluster (this package) hosted inside a static gang allocation (Slurm / K8s /
Cloud-TPU queued resources), with a secure containerized bring-up protocol.
"""
from repro.core.autoscaler import Autoscaler, AutoscalerConfig, ScalingEvent
from repro.core.cluster import ContainerSpec, SyndeoCluster
from repro.core.object_store import (GlobalObjectStore, NodeStore, ObjectRef,
                                     QuotaExceededError, TenantQuota)
from repro.core.scheduler import (DrainState, Scheduler, SchedulerConfig,
                                  TenantState, WorkerIndex, WorkerInfo)
from repro.core.security import (Capability, NonceCache, SecurityError,
                                 Tenant, UnprivilegedProfile)
from repro.core.simulator import SimCluster, SimCostModel
from repro.core.task_graph import Task, TaskSpec, TaskState

__all__ = [
    "Autoscaler", "AutoscalerConfig", "ScalingEvent",
    "ContainerSpec", "SyndeoCluster", "DrainState", "GlobalObjectStore",
    "NodeStore",
    "ObjectRef", "QuotaExceededError", "TenantQuota",
    "Scheduler", "SchedulerConfig", "TenantState", "WorkerIndex",
    "WorkerInfo",
    "Capability", "NonceCache", "SecurityError", "Tenant",
    "UnprivilegedProfile", "SimCluster",
    "SimCostModel", "Task", "TaskSpec", "TaskState",
]
