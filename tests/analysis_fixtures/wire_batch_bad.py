"""known-bad: batch sub-ops drift from the handler set (SYN-W001 on a
queued sub-op with no handler, SYN-W002 when the only send of an op is
a sub-op missing a required field)."""


class Server:
    def __init__(self):
        self.acks = []

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "ack":
            self.acks.append(msg["task"])
            return {"ok": True}
        if op == "batch":
            return {"ok": True,
                    "replies": [self.dispatch(s)
                                for s in msg.get("ops") or []]}
        return {"ok": False, "error": f"bad op {op}"}


def _request(host, port, token, msg):
    raise NotImplementedError


def client_poll(pending):
    pending.append({"op": "ack", "worker": "w"})    # missing "task"
    pending.append({"op": "flysh", "worker": "w"})  # typo: no handler
    return _request("h", 1, "t", {"op": "batch", "ops": pending})
