"""Broadcast-tree delivery equivalence and the delta-encoded spill tier.

The property test drives random object graphs through BOTH delivery
shapes -- a binomial broadcast tree and N independent direct fetches --
and asserts every consumer lands byte-identical blobs, with spilled
sources restored through the delta-chunk manifest and a producer killed
mid-broadcast served by surviving replicas (relay, never lineage).
Every run ends in tests/_invariants.py's global storage check, which now
also asserts replica coherence across all landed copies."""
import pickle
import random
import tempfile

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import GlobalObjectStore, NodeStore, ObjectRef
from repro.core.object_store import (SPILL_CHUNK_MAX, SPILL_CHUNK_MIN,
                                     spill_chunk_spans)
from repro.core.security import mint_cluster_token

from _invariants import check_invariants

TOKEN = mint_cluster_token()


def _build(n_nodes, tmp, guard):
    g = GlobalObjectStore(shards=4)
    g.set_access_guard(TOKEN)
    g.register_node(NodeStore("head", capacity_bytes=1 << 30))
    for i in range(n_nodes):
        g.register_node(NodeStore(f"w{i}", capacity_bytes=1 << 30,
                                  spill_dir=tmp))
    if guard:
        g.set_transfer_guard(True)
    return g


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 12), st.integers(1, 4),
       st.booleans(), st.booleans(), st.booleans())
def test_broadcast_tree_matches_direct_fetches(seed, n_nodes, n_objects,
                                               spill_source, kill_producer,
                                               guard):
    """Property: tree delivery == N direct fetches, byte for byte, for
    random object graphs -- sources spilled to the delta tier before the
    broadcast, producers dying between rounds, ticket guard on or off."""
    rng = random.Random(seed)
    with tempfile.TemporaryDirectory() as tmp_a, \
            tempfile.TemporaryDirectory() as tmp_b:
        tree = _build(n_nodes, tmp_a, guard)
        direct = _build(n_nodes, tmp_b, guard)
        expected = {}
        refs = []
        for i in range(n_objects):
            producer = f"w{rng.randrange(n_nodes)}"
            value = rng.randbytes(rng.randint(100, 50_000))
            tenant = rng.choice(["alice", "bob"])
            ref = tree.put(producer, value, ref_id=f"o{i}", tenant=tenant)
            direct.put(producer, value, ref_id=f"o{i}", tenant=tenant)
            expected[ref.id] = pickle.dumps(
                value, protocol=pickle.HIGHEST_PROTOCOL)
            if spill_source:
                # the broadcast's root replica serves from the delta-
                # encoded disk tier, not memory
                assert tree._nodes[producer].spill(ref)
            refs.append((ref, producer, tenant))
        consumers = [f"w{i}" for i in range(n_nodes)]
        for ref, producer, tenant in refs:
            survivors = [c for c in consumers if c != producer]

            def on_round(k, _ref=ref, _producer=producer):
                # a producer dying between rounds must be absorbed by
                # re-planning: consumers that landed copies in earlier
                # rounds serve the rest (relay, never lineage)
                if kill_producer and k == 1 and len(survivors) >= 2:
                    tree.unregister_node(_producer)

            tree.broadcast(ref, survivors, on_round=on_round)
            for c in survivors:
                if c not in tree.locations(ref):
                    # permissible only if delivery was genuinely
                    # impossible (single holder died before relaying)
                    assert kill_producer
                    continue
                got = tree._nodes[c].export_blob(ref)
                assert got == expected[ref.id], \
                    f"{ref.id} diverged at consumer {c}"
            for c in survivors:
                ticket = (direct.grant_fetch(ref, c, tenant)
                          if guard else None)
                direct.fetch(c, ref, ticket=ticket)
                assert direct._nodes[c].export_blob(ref) \
                    == expected[ref.id]
        assert tree.stats["head_relayed_bytes"] == 0
        check_invariants(tree, expect_zero_reconstructions=True)
        check_invariants(direct, expect_zero_reconstructions=True)


def test_broadcast_rounds_grow_logarithmically():
    """32 consumers from one producer land in ~log2 rounds, every edge
    ticketed, and the head serves zero payload bytes."""
    with tempfile.TemporaryDirectory() as tmp:
        g = _build(33, tmp, guard=True)
        ref = g.put("w0", b"x" * 100_000, ref_id="fat")
        consumers = [f"w{i}" for i in range(1, 33)]
        delivered = g.broadcast(ref, consumers)
        assert delivered > 0
        assert all(c in g.locations(ref) for c in consumers)
        assert g.stats["broadcast_rounds"] <= 7      # ceil(log2(32)) + tail
        assert g.stats["tree_edges"] == 32
        assert g.stats["head_relayed_bytes"] == 0
        check_invariants(g, expect_fetchable=["fat"])


def test_choose_source_deterministic_under_equal_load():
    """Tie-breaking is by sorted node id before link load: equal-load
    replicas must rank identically regardless of registration order."""
    ranks = []
    for order in (range(4), reversed(range(4))):
        g = GlobalObjectStore(shards=1)
        g.register_node(NodeStore("head", capacity_bytes=1 << 30))
        for i in order:
            g.register_node(NodeStore(f"w{i}", capacity_bytes=1 << 30))
        ref = g.put("w2", b"y" * 64, ref_id="o")
        for n in ("w0", "w1", "w3"):
            g.fetch(n, ref)
        rank = g.rank_sources(ref, "head")
        loads = [g.link_load(n) for n in rank]
        # within an equal-load tie, node ids ascend -- never dict order
        for (a, la), (b, lb) in zip(zip(rank, loads),
                                    zip(rank[1:], loads[1:])):
            if la == lb:
                assert a < b, f"tie ({a}, {b}) not id-ordered in {rank}"
        ranks.append(rank)
    assert ranks[0] == ranks[1], "rank_sources depends on insertion order"


def test_delta_spill_rewrites_only_changed_chunks(tmp_path):
    """A respilled generation shares unchanged content chunks with its
    predecessor: bytes written shrink and the restore is byte-exact."""
    store = NodeStore("w0", capacity_bytes=1 << 30,
                      spill_dir=str(tmp_path))
    payload = bytearray(random.Random(7).randbytes(300_000))
    blob = pickle.dumps(bytes(payload))
    ref = ObjectRef("churn", len(blob))
    store.put_blob(ref, blob)
    assert store.spill(ref)
    assert store.stats["delta_spill_bytes_saved"] == 0  # first generation
    assert store.export_blob(ref) == blob

    # restore-on-access promoted it back to memory; mutate a slice and
    # spill the new generation -- only touched chunks rewrite
    assert store.get(ref) == bytes(payload)
    payload[1000:1100] = b"\x00" * 100
    blob2 = pickle.dumps(bytes(payload))
    ref2 = ObjectRef("churn", len(blob2))
    store.put_blob(ref2, blob2)
    assert store.spill(ref2)
    assert store.export_blob(ref2) == blob2
    # most content chunks were shared with generation 1: the churn paid
    # far less than a whole-blob rewrite
    assert store.stats["delta_spill_bytes_saved"] > len(blob2) // 2
    assert store.stats["spills"] == 2


def test_spill_chunk_spans_cover_and_bound():
    """Content-defined chunking: spans tile the blob exactly and every
    non-final chunk respects the min/max bounds."""
    rng = random.Random(11)
    for size in (0, 1, 5000, 123_457, 400_000):
        blob = rng.randbytes(size)
        spans = spill_chunk_spans(blob)
        assert b"".join(blob[a:b] for a, b in spans) == blob
        for a, b in spans[:-1]:
            assert SPILL_CHUNK_MIN <= b - a <= SPILL_CHUNK_MAX


def test_disk_tier_promotes_on_access_frequency(tmp_path):
    """promote_after > 1 serves cold reads from disk and promotes the
    blob to memory only once it proves hot."""
    store = NodeStore("w0", capacity_bytes=1 << 30,
                      spill_dir=str(tmp_path), promote_after=3)
    blob = pickle.dumps(b"z" * 50_000)
    ref = ObjectRef("cold", len(blob))
    store.put_blob(ref, blob)
    assert store.spill(ref)
    store.get(ref)
    store.get(ref)
    assert store.stats["promotions"] == 0       # still disk-resident
    store.get(ref)
    assert store.stats["promotions"] == 1       # third access = hot
    assert store.stats["restores"] == 1
