"""Serving-plane router: cluster-level continuous batching over replicas.

The engine (`serve/engine.py`) batches at the *slot* level -- B decode
slots over one static KV cache. This router composes a second batching
layer above it: a fleet of long-running replica actors, each wrapping an
engine, fed by token-level admission so the cluster-level batcher and the
engine's slot-level batcher stay full together.

Admission (per `submit`):

  1. fill free decode slots first -- a replica with an empty slot starts
     the request on its very next prefill, so those replicas win over any
     amount of queueing elsewhere,
  2. ties (and the no-free-slot case) break by least outstanding tokens:
     the replica that owes the fewest decode steps to already-admitted
     requests finishes soonest,
  3. per-replica queues are bounded (`max_queue_per_replica`); when every
     replica is full the request is *shed to the retry buffer* rather
     than dropped -- `tick()` re-admits it as capacity frees. Only a full
     retry buffer drops (counted in ``stats["shed"]``).

Fault handling:

  * `fail_replica` (abrupt death, e.g. its host worker crashed): every
    in-flight request the replica held is reclaimed, its partial output
    reset, and re-routed to survivors. Outputs stay correct because the
    engine is deterministic per prompt -- a re-decoded request produces
    the same tokens.
  * `retire_replica` (graceful scale-down / drain): admissions stop, the
    replica finishes its in-flight decodes (`run_until_drained`), and
    only then is it removed -- the drain plane's no-dropped-work rule.
  * `Router.recover` (router death): a fresh router adopts the live
    replicas; each quiesces (drains its in-flight work to completion, so
    nothing the dead router admitted is lost) and re-registers empty.

Replica handles are duck-typed: anything with the engine surface
(``add_request`` / ``tick`` / ``pop_completed`` / ``run_until_drained`` /
``free_slots`` / ``queue_len`` / ``outstanding_tokens``) serves -- a
local ``StubEngine``/``ServeEngine``, the simulator's virtual replicas,
or `ActorReplicaHandle`, which adapts the same surface over the wire
protocol's ``actor_call`` ops to a `ReplicaActor` hosted by a remote
worker.

`stats_sink`, called after every tick with a snapshot
(requests/shed/completed/p99_ms/replicas), is how the head's `metrics`
op gets its serving gauges: point it at ``HeadServer.serve_stats.update``.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.metrics import MetricsRegistry
from repro.serve.engine import Request, StubEngine


class Router:
    """Continuous-batching request router over replica handles."""

    def __init__(self, max_queue_per_replica: int = 8,
                 max_retry_backlog: int = 64,
                 p99_window: int = 512,
                 clock: Optional[Callable[[], float]] = None,
                 stats_sink: Optional[Callable[[Dict[str, float]],
                                               Any]] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.max_queue = max(0, int(max_queue_per_replica))
        self.max_retry = max(0, int(max_retry_backlog))
        self.clock = clock or time.monotonic
        self.stats_sink = stats_sink
        # observability: queue-depth histogram (one observation per
        # tick) and shed-time depth histogram -- the conformance checker
        # holds their counts against stats["ticks"] / stats["shed"]
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.replicas: Dict[str, Any] = {}
        self._draining: set = set()          # no new admissions
        self._inflight: Dict[str, Dict[int, Request]] = {}
        self._submit_t: Dict[int, float] = {}
        self._retry: "collections.deque[Request]" = collections.deque()
        self._latencies: "collections.deque[float]" = collections.deque(
            maxlen=max(1, int(p99_window)))
        self.stats = {"requests": 0, "shed": 0, "completed": 0,
                      "rerouted": 0, "retried": 0, "ticks": 0}

    # -- membership -----------------------------------------------------------

    def add_replica(self, replica_id: str, handle: Any):
        if replica_id in self.replicas:
            raise ValueError(f"replica {replica_id!r} already registered")
        self.replicas[replica_id] = handle
        self._inflight.setdefault(replica_id, {})
        self._draining.discard(replica_id)

    def retire_replica(self, replica_id: str,
                       max_ticks: int = 10000) -> List[Request]:
        """Graceful scale-down of one replica: stop admissions, let it
        finish every in-flight decode, unregister it. Returns the
        requests it completed on the way out -- none are dropped."""
        handle = self.replicas.get(replica_id)
        if handle is None:
            return []
        self._draining.add(replica_id)
        done = list(handle.run_until_drained(max_ticks=max_ticks))
        finished = self._harvest(replica_id, done)
        leftover = self._inflight.pop(replica_id, {})
        del self.replicas[replica_id]
        self._draining.discard(replica_id)
        # anything the engine could not finish inside max_ticks is
        # re-routed like a failure, not silently lost
        self._reroute(leftover.values())
        return finished

    def fail_replica(self, replica_id: str) -> int:
        """Abrupt replica death: reclaim every request it held (queued or
        mid-decode), reset partial outputs, re-route to survivors (or the
        retry buffer). Returns the number of requests re-routed."""
        self.replicas.pop(replica_id, None)
        self._draining.discard(replica_id)
        lost = self._inflight.pop(replica_id, {})
        n = len(lost)
        self.stats["rerouted"] += n
        self._reroute(lost.values())
        return n

    @classmethod
    def recover(cls, replicas: Dict[str, Any],
                **kwargs) -> "tuple[Router, List[Request]]":
        """Router-death recovery: a fresh router adopts live replicas.
        Each quiesces -- drains its in-flight work to completion (those
        completions are returned, not lost) -- and re-registers empty."""
        router = cls(**kwargs)
        recovered: List[Request] = []
        for rid in sorted(replicas):
            handle = replicas[rid]
            for req in handle.run_until_drained():
                req.done = True
                recovered.append(req)
            router.add_replica(rid, handle)
        return router, recovered

    # -- admission ------------------------------------------------------------

    def _candidates(self) -> List[str]:
        return [rid for rid in self.replicas if rid not in self._draining]

    def _place(self, req: Request) -> Optional[str]:
        """Token-level admission: free decode slots first, then bounded
        queues; least-outstanding-tokens tiebreak (replica id breaks the
        remaining ties deterministically)."""
        cands = self._candidates()
        free = [r for r in cands if self.replicas[r].free_slots > 0]
        pool = free or [r for r in cands
                        if self.replicas[r].queue_len < self.max_queue]
        if not pool:
            return None
        rid = min(pool, key=lambda r: (self.replicas[r].outstanding_tokens,
                                       r))
        self.replicas[rid].add_request(req)
        self._inflight[rid][req.id] = req
        self._submit_t.setdefault(req.id, self.clock())
        return rid

    def submit(self, req: Request) -> bool:
        """Admit one request. True = accepted (placed now, or parked in
        the retry buffer); False = shed (every replica AND the retry
        buffer are full -- the caller may retry later)."""
        self._submit_t[req.id] = self.clock()
        if self._place(req) is not None:
            self.stats["requests"] += 1
            return True
        if len(self._retry) < self.max_retry:
            self._retry.append(req)
            self.stats["requests"] += 1
            return True
        self._submit_t.pop(req.id, None)
        self.stats["shed"] += 1
        self.metrics.histogram("syndeo_router_shed_depth").observe(
            self.queue_depth())
        return False

    def _reroute(self, reqs) -> None:
        for req in reqs:
            req.output = []
            req.done = False
            if self._place(req) is None:
                self._retry.append(req)   # unbounded here: reclaimed work
                                          # is never shed a second time

    # -- the serving tick -----------------------------------------------------

    def _harvest(self, rid: str, done) -> List[Request]:
        """Fold a replica's completions back into the requests this
        router tracks (remote handles may return rebuilt twins)."""
        out: List[Request] = []
        inflight = self._inflight.get(rid, {})
        now = self.clock()
        for r in done:
            orig = inflight.pop(r.id, None)
            if orig is not None and orig is not r:
                orig.output = list(r.output)
            req = orig or r
            req.done = True
            t0 = self._submit_t.pop(req.id, None)
            if t0 is not None:
                self._latencies.append(now - t0)
            self.stats["completed"] += 1
            out.append(req)
        return out

    def tick(self) -> List[Request]:
        """One router iteration: re-admit the retry buffer into freed
        capacity, tick every replica one decode step, harvest
        completions. Returns the requests that finished this tick."""
        for _ in range(len(self._retry)):
            req = self._retry.popleft()
            if self._place(req) is None:
                self._retry.append(req)
                break
            self.stats["retried"] += 1
        finished: List[Request] = []
        for rid in sorted(self.replicas):
            handle = self.replicas[rid]
            handle.tick()
            finished.extend(self._harvest(rid, handle.pop_completed()))
        self.stats["ticks"] += 1
        self.metrics.histogram("syndeo_router_queue_depth").observe(
            self.queue_depth())
        if self.stats_sink is not None:
            self.stats_sink(self.snapshot())
        return finished

    def flush(self, max_ticks: int = 100000) -> List[Request]:
        """Tick until nothing is in flight anywhere (or the tick budget
        runs out); returns everything completed along the way."""
        out: List[Request] = []
        for _ in range(max_ticks):
            if self.idle():
                break
            out.extend(self.tick())
        return out

    def idle(self) -> bool:
        return (not self._retry
                and not any(self._inflight.get(r) for r in self.replicas))

    # -- observability --------------------------------------------------------

    def inflight_count(self) -> int:
        return (len(self._retry)
                + sum(len(m) for m in self._inflight.values()))

    def p99_ms(self) -> float:
        """p99 end-to-end latency over the sliding completion window."""
        if not self._latencies:
            return 0.0
        window = sorted(self._latencies)
        idx = min(len(window) - 1, int(0.99 * len(window)))
        return window[idx] * 1000.0

    def queue_depth(self) -> float:
        """Mean per-replica backlog (queued + retry share) -- the SLO
        autoscaler's second signal."""
        n = max(1, len(self.replicas))
        queued = sum(h.queue_len for h in self.replicas.values())
        return (queued + len(self._retry)) / n

    def snapshot(self) -> Dict[str, float]:
        return {"requests": self.stats["requests"],
                "shed": self.stats["shed"],
                "completed": self.stats["completed"],
                "p99_ms": self.p99_ms(),
                "replicas": len(self.replicas)}


class ReplicaActor:
    """Worker-hosted service actor wrapping an engine: the factory the
    serving plane registers under ``actor_factories={"replica": ...}`` in
    `run_worker`. One `handle(payload)` call per routed op:

      {"kind": "submit", "id", "prompt", "max_new_tokens", "eos_id"}
          -> {"accepted": True}
      {"kind": "tick"}   -> {"active": n, "done": [[id, output], ...],
                             "stats": {free_slots, queue_len,
                                       outstanding_tokens}}
      {"kind": "stats"}  -> the same stats dict
      {"kind": "drain"}  -> {"done": [[id, output], ...]} (run to empty)

    `drain()` (called on the actor_exit directive) finishes every
    in-flight decode before the worker acks the exit."""

    def __init__(self, batch_slots: int = 4, engine: Any = None,
                 weights_version: Optional[str] = None):
        self.engine = engine or StubEngine(batch_slots)
        self.weights_version = weights_version

    def _stats(self) -> Dict[str, int]:
        return {"free_slots": self.engine.free_slots,
                "queue_len": self.engine.queue_len,
                "outstanding_tokens": self.engine.outstanding_tokens}

    def handle(self, payload: Optional[Dict[str, Any]]) -> Dict[str, Any]:
        kind = (payload or {}).get("kind")
        if kind == "submit":
            self.engine.add_request(Request(
                id=int(payload["id"]),
                prompt=[int(t) for t in payload.get("prompt") or []],
                max_new_tokens=int(payload.get("max_new_tokens", 16)),
                eos_id=int(payload.get("eos_id", -1))))
            return {"accepted": True}
        if kind == "tick":
            n = self.engine.tick()
            done = self.engine.pop_completed()
            return {"active": n,
                    "done": [[r.id, list(r.output)] for r in done],
                    "stats": self._stats()}
        if kind == "stats":
            return self._stats()
        if kind == "drain":
            done = self.engine.run_until_drained()
            return {"done": [[r.id, list(r.output)] for r in done]}
        raise ValueError(f"unknown replica op {kind!r}")

    def drain(self):
        self.engine.run_until_drained()


class ActorReplicaHandle:
    """Engine-surface adapter over a remote `ReplicaActor`: `call` is any
    synchronous payload -> value transport (e.g. the head's actor_call /
    actor_result round trip). Slot/queue stats are the remote's own,
    refreshed on every tick, with local adjustments between ticks so
    back-to-back admissions in one router pass don't all pick the same
    replica on stale numbers."""

    def __init__(self, call: Callable[[Dict[str, Any]], Dict[str, Any]]):
        self._call = call
        self._stats = {"free_slots": 0, "queue_len": 0,
                       "outstanding_tokens": 0}
        self._completed: List[Request] = []
        self.refresh()

    def refresh(self):
        self._stats = dict(self._call({"kind": "stats"}))

    @property
    def free_slots(self) -> int:
        return int(self._stats.get("free_slots", 0))

    @property
    def queue_len(self) -> int:
        return int(self._stats.get("queue_len", 0))

    @property
    def outstanding_tokens(self) -> int:
        return int(self._stats.get("outstanding_tokens", 0))

    def add_request(self, req: Request):
        self._call({"kind": "submit", "id": req.id, "prompt": req.prompt,
                    "max_new_tokens": req.max_new_tokens,
                    "eos_id": req.eos_id})
        self._stats["free_slots"] = max(0, self.free_slots - 1)
        self._stats["queue_len"] = self.queue_len + 1
        self._stats["outstanding_tokens"] = (self.outstanding_tokens
                                             + req.max_new_tokens)

    def _rebuild(self, done) -> List[Request]:
        return [Request(id=int(rid), prompt=[], output=list(out), done=True)
                for rid, out in done or []]

    def tick(self) -> int:
        got = self._call({"kind": "tick"})
        self._stats = dict(got.get("stats") or self._stats)
        self._completed.extend(self._rebuild(got.get("done")))
        return int(got.get("active", 0))

    def pop_completed(self) -> List[Request]:
        out, self._completed = self._completed, []
        return out

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        got = self._call({"kind": "drain"})
        out = self.pop_completed() + self._rebuild(got.get("done"))
        self.refresh()
        return out
