"""Chaos fault-injection harness for the drain pipeline.

Randomized scenarios kill (`fail_worker_at`) and drain (`drain_worker_at`)
workers mid-wave on the virtual-clock backend, which drives the *real*
Scheduler / GlobalObjectStore code. The invariants under test:

  * every submitted task still reaches FINISHED -- never FAILED -- no
    matter when workers die or drain (>= 25 seeded scenarios),
  * after a drain completes, no object read ever raises: every object
    that was fetchable before the drain is fetchable after it,
  * drains are provably no worse than recompute: migrated hot objects are
    served from survivors with ZERO lineage re-execution of their
    producers (the drop path, by contrast, must re-execute).

Seeds come through the hypothesis fallback when hypothesis is missing, so
runs are reproducible either way.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from _invariants import check_invariants, check_metrics_conformance
from repro.core import (SchedulerConfig, SimCluster, SimCostModel, TaskSpec,
                        TaskState)

TERMINAL = {TaskState.FINISHED, TaskState.FAILED, TaskState.CANCELLED}


def _mk_sim(seed: int, n_workers: int = 6, task_s: float = 0.1) -> SimCluster:
    cost = SimCostModel(task_time_s=lambda s: task_s,
                        result_bytes=lambda s: 4096.0, jitter=0.1,
                        result_location="worker")
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9), seed=seed)
    sim.add_workers(n_workers)
    return sim


def _run_until_terminal(sim: SimCluster, ids, horizon_s: float = 300.0):
    """Drive the sim until every task in `ids` is terminal (monitor ticks
    keep drains/stragglers moving), with a virtual-time safety horizon."""
    deadline = sim.now + horizon_s

    def monitor():
        if sim.now > deadline:
            raise AssertionError("chaos scenario did not converge")
        sim.scheduler.check_stragglers()
        sim.scheduler.check_drains(sim.now)
        if {sim.scheduler.graph.tasks[i].state for i in ids} <= TERMINAL:
            return
        sim._post(0.05, monitor)

    sim._post(0.05, monitor)
    sim.run()
    states = {sim.scheduler.graph.tasks[i].state for i in ids}
    assert states <= TERMINAL, f"non-terminal tasks remain: {states}"


def _produce(sim: SimCluster, n: int):
    """Run a producer wave; return the output refs (spread over workers)."""
    sim.run_wave([TaskSpec(fn=None, group="produce", max_retries=10)
                  for _ in range(n)])
    refs = [t.output for t in sim.scheduler.graph.tasks.values()
            if t.output is not None]
    assert len(refs) == n
    return refs


def _fetchable(sim: SimCluster, refs):
    return {r.id for r in refs if sim.store.locations(r)}


# ------------------------------------------------------------- chaos harness

@pytest.mark.parametrize("seed", range(25))
def test_chaos_kill_and_drain_mid_wave(seed):
    """>= 25 randomized scenarios: workers are killed and drained at random
    times while a dependent two-stage wave is in flight. Every task must
    complete, nothing may end FAILED, and after the run every object the
    consumers still reference is readable."""
    rng = random.Random(seed)
    n_workers = 6
    sim = _mk_sim(seed, n_workers=n_workers, task_s=0.1)
    refs = _produce(sim, rng.randint(8, 16))

    # consumers depend on 1-3 random producer outputs each
    t0 = sim.now
    ids = []
    for _ in range(rng.randint(10, 20)):
        deps = rng.sample(refs, rng.randint(1, 3))
        ids.append(sim.submit(TaskSpec(fn=None, group="consume",
                                       max_retries=10), deps=deps).id)

    # chaos: at most n_workers - 2 removals so the wave can always finish
    workers = [f"w{i}" for i in range(n_workers)]
    rng.shuffle(workers)
    n_remove = rng.randint(1, n_workers - 2)
    for wid in workers[:n_remove]:
        at = t0 + rng.uniform(0.0, 1.0)
        if rng.random() < 0.5:
            sim.fail_worker_at(wid, at)
        else:
            deadline = rng.choice([None, 0.05, 0.3])
            sim.drain_worker_at(wid, at, deadline_s=deadline)

    _run_until_terminal(sim, ids)
    states = [sim.scheduler.graph.tasks[i].state for i in ids]
    assert all(s == TaskState.FINISHED for s in states), states

    # no object read raises once the dust settles: anything with a live
    # copy must actually deserialize (a *kill* may legitimately take sole
    # copies with it -- that is what lineage is for -- but a read of any
    # surviving object, migrated or not, must work)
    for i in ids:
        out = sim.scheduler.graph.tasks[i].output
        assert out is not None
        if sim.store.locations(out):
            sim.store.get("head", out)
    for r in refs:
        if sim.store.locations(r):
            sim.store.get("head", r)
    check_invariants(sim.store)
    # exported telemetry still equals ground truth after the chaos
    check_metrics_conformance(sim.store, sim.scheduler)


@pytest.mark.parametrize("seed", range(10))
def test_chaos_drain_only_never_loses_objects(seed):
    """Drain-only chaos: with no failures injected, a drain may never cost
    an object nor a lineage re-execution -- reads after the drain are
    served from survivors."""
    rng = random.Random(1000 + seed)
    n_workers = 5
    sim = _mk_sim(1000 + seed, n_workers=n_workers, task_s=0.08)
    refs = _produce(sim, rng.randint(6, 12))
    pre = _fetchable(sim, refs)
    assert pre == {r.id for r in refs}

    t0 = sim.now
    ids = [sim.submit(TaskSpec(fn=None, group="consume", max_retries=10),
                      deps=[rng.choice(refs)]).id
           for _ in range(rng.randint(6, 12))]
    workers = [f"w{i}" for i in range(n_workers)]
    rng.shuffle(workers)
    for wid in workers[:rng.randint(1, n_workers - 2)]:
        sim.drain_worker_at(wid, t0 + rng.uniform(0.0, 0.5),
                            deadline_s=rng.choice([None, 0.2]))

    reconstructed_before = sim.scheduler.stats["reconstructed"]
    _run_until_terminal(sim, ids)

    assert all(sim.scheduler.graph.tasks[i].state == TaskState.FINISHED
               for i in ids)
    assert _fetchable(sim, refs) == pre
    for r in refs:
        sim.store.get("head", r)          # must not raise
    assert sim.scheduler.stats["reconstructed"] == reconstructed_before
    assert sim.store.stats["reconstructions"] == 0
    check_invariants(sim.store, expect_fetchable=pre)
    check_metrics_conformance(sim.store, sim.scheduler)


# ------------------------------------------------- drain-preservation property

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 8), st.integers(1, 6))
def test_drain_preserves_fetchable_set(seed, n_workers, n_drain):
    """Property: after draining ANY subset of workers (always leaving one
    survivor), the fetchable object set equals the pre-drain set, migrated
    objects are served from survivors, and ZERO producer tasks re-execute
    for hot objects."""
    rng = random.Random(seed)
    sim = _mk_sim(seed, n_workers=n_workers, task_s=0.05)
    refs = _produce(sim, rng.randint(4, 12))
    pre = _fetchable(sim, refs)
    pre_locs = {r.id: set(sim.store.locations(r)) for r in refs}

    victims = [f"w{i}" for i in range(min(n_drain, n_workers - 1))]
    drained = set(victims)
    for wid in victims:
        sim.drain_worker_at(wid, sim.now)
    sim.run()

    for wid in victims:
        assert wid not in sim.scheduler.workers     # release happened
    assert _fetchable(sim, refs) == pre
    for r in refs:
        locs = sim.store.locations(r)
        assert locs and not (locs & drained)        # served by survivors
        sim.store.get("head", r)                    # and actually readable
    # zero lineage re-execution for hot objects -- drains moved, not dropped
    assert sim.scheduler.stats["reconstructed"] == 0
    assert sim.store.stats["reconstructions"] == 0
    # every object that lived only on drained workers needed >= 1 move
    # (chained drains may move an object more than once)
    solely_on_drained = sum(1 for r in refs if pre_locs[r.id] <= drained)
    assert sim.store.stats["migrations"] >= solely_on_drained
    check_invariants(sim.store, expect_fetchable=pre,
                     scheduler=sim.scheduler,
                     expect_zero_reconstructions=True)
    check_metrics_conformance(sim.store, sim.scheduler)


# ------------------------------------- p2p migration-path chaos (two-phase)

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 7), st.integers(1, 3))
def test_chaos_p2p_migration_faults_keep_invariants(seed, n_workers,
                                                    n_events):
    """Property: random object graphs moved by the two-phase p2p drain
    protocol keep the global invariants (directory subset of reality,
    exactly-one owner per live ref, anchored in-flight moves) under
    randomly timed kills of sources AND destinations mid-move. Fat blobs
    over a slow migration link keep moves in flight long enough for the
    faults to land inside the push window."""
    rng = random.Random(seed)
    sizes = [4096, 262_144, 1 << 20]
    cost = SimCostModel(
        task_time_s=lambda s: 0.05,
        result_bytes=lambda s: float(rng.choice(sizes)),
        jitter=0.0, result_location="worker", data_plane="p2p",
        migration_bandwidth_Bps=2.0e6)        # ~0.5s per fat move
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9,
                                           migration_timeout_s=2.0),
                     seed=seed)
    sim.add_workers(n_workers)
    refs = _produce(sim, rng.randint(6, 12))
    workers = [f"w{i}" for i in range(n_workers)]
    rng.shuffle(workers)
    victims = workers[:min(n_events + 1, n_workers - 2)]
    # the first victims drain (their moves go in flight); later events
    # kill workers -- sometimes a drain's source, sometimes a move's
    # destination -- inside the migration window
    sim.drain_worker_at(victims[0], 0.0)
    for wid in victims[1:]:
        at = rng.uniform(0.05, 1.5)
        if rng.random() < 0.5:
            sim.fail_worker_at(wid, at)
        else:
            sim.drain_worker_at(wid, at)
    sim.run()
    check_invariants(sim.store)
    check_metrics_conformance(sim.store, sim.scheduler)
    # drained-only workers are gone; killed ones too
    for wid in victims:
        assert wid not in sim.scheduler.workers
    # surviving copies actually deserialize
    for r in refs:
        if sim.store.locations(r):
            sim.store.get("head", r)


def test_drop_retirement_reexecutes_drain_does_not():
    """The head-to-head: retiring object-holding workers via the drop path
    (retire_worker) forces lineage re-execution when consumers arrive;
    the drain path serves every consumer without recompute."""
    results = {}
    for mode in ("drop", "drain"):
        sim = _mk_sim(42, n_workers=6, task_s=0.05)
        refs = _produce(sim, 12)
        victims = sorted({next(iter(sim.store.locations(r))) for r in refs})[:3]
        if mode == "drain":
            for wid in victims:
                sim.drain_worker_at(wid, sim.now)
            sim.run()
        else:
            for wid in victims:
                assert sim.scheduler.retire_worker(wid)
        before = sim.scheduler.stats["reconstructed"]
        ids = [sim.submit(TaskSpec(fn=None, group="consume",
                                   max_retries=10), deps=[r]).id
               for r in refs]
        _run_until_terminal(sim, ids)
        assert all(sim.scheduler.graph.tasks[i].state == TaskState.FINISHED
                   for i in ids)
        results[mode] = sim.scheduler.stats["reconstructed"] - before
    assert results["drain"] == 0
    assert results["drop"] > 0


# ---------------------------------------------------------- drain lifecycle

def test_draining_worker_gets_no_new_placements():
    sim = _mk_sim(0, n_workers=2, task_s=0.2)
    sim.scheduler.begin_drain("w0")
    ids = [sim.submit(TaskSpec(fn=None, max_retries=10)).id
           for _ in range(4)]
    _run_until_terminal(sim, ids)
    assert all(sim.scheduler.graph.tasks[i].worker == "w1" for i in ids)


def test_busy_worker_drains_after_tasks_finish():
    sim = _mk_sim(0, n_workers=2, task_s=0.3)
    ids = [sim.submit(TaskSpec(fn=None, max_retries=10)).id
           for _ in range(2)]
    sim.drain_worker_at("w0", 0.05)     # both workers busy at the notice
    _run_until_terminal(sim, ids)
    assert "w0" not in sim.scheduler.workers
    assert all(sim.scheduler.graph.tasks[i].state == TaskState.FINISHED
               for i in ids)
    assert sim.scheduler.stats["preempted"] == 0   # no deadline: tasks ran out


def test_drain_deadline_preempts_and_requeues():
    sim = _mk_sim(0, n_workers=2, task_s=5.0)
    t = sim.submit(TaskSpec(fn=None, max_retries=10))
    assert t.state == TaskState.RUNNING
    victim = t.worker
    sim.drain_worker_at(victim, 0.1, deadline_s=0.2)
    _run_until_terminal(sim, [t.id], horizon_s=60.0)
    assert sim.scheduler.stats["preempted"] >= 1
    assert t.state == TaskState.FINISHED
    assert t.worker != victim           # finished on the survivor
    assert victim not in sim.scheduler.workers


def test_cancel_drain_restores_placement():
    sim = _mk_sim(0, n_workers=1, task_s=0.05)
    assert sim.scheduler.begin_drain("w0")
    t = sim.submit(TaskSpec(fn=None, max_retries=10))
    assert t.state == TaskState.READY    # sole worker is draining
    assert sim.scheduler.cancel_drain("w0")
    assert t.state == TaskState.RUNNING and t.worker == "w0"
    _run_until_terminal(sim, [t.id])


def test_concurrent_drains_of_coholding_workers_keep_object():
    """Two draining workers that hold the only two copies of an object must
    not each count the other as a survivor: the object still ends up on a
    real survivor with zero reconstruction."""
    sim = _mk_sim(0, n_workers=3, task_s=0.05)
    [ref] = _produce(sim, 1)
    src = next(iter(sim.store.locations(ref)))
    others = [w for w in ("w0", "w1", "w2") if w != src]
    sim.store.get(others[0], ref)            # replicate: copies on 2 nodes
    assert sim.store.locations(ref) == {src, others[0]}
    sim.drain_worker_at(src, sim.now)
    sim.drain_worker_at(others[0], sim.now)
    sim.run()
    assert src not in sim.scheduler.workers
    assert others[0] not in sim.scheduler.workers
    locs = sim.store.locations(ref)
    assert locs and locs <= {others[1], "head"}
    sim.store.get("head", ref)               # must not raise
    assert sim.store.stats["reconstructions"] == 0


def test_preemption_does_not_burn_retry_budget():
    """A drain-deadline preemption must not count against max_retries."""
    sim = _mk_sim(0, n_workers=2, task_s=2.0)
    t = sim.submit(TaskSpec(fn=None, max_retries=0))   # zero retry budget
    assert t.state == TaskState.RUNNING
    victim = t.worker
    sim.drain_worker_at(victim, 0.05, deadline_s=0.1)
    _run_until_terminal(sim, [t.id], horizon_s=60.0)
    assert sim.scheduler.stats["preempted"] >= 1
    assert t.state == TaskState.FINISHED               # not FAILED
    assert t.attempts == 1                             # relaunch re-charged it


def test_migration_hands_off_owner():
    sim = _mk_sim(0, n_workers=2, task_s=0.05)
    [ref] = _produce(sim, 1)
    src = next(iter(sim.store.locations(ref)))
    assert sim.store.owner_of(ref) == src
    sim.drain_worker_at(src, sim.now)
    sim.run()
    dst = sim.store.owner_of(ref)
    assert dst is not None and dst != src
    assert sim.store.locations(ref) == {dst}
