"""syndeo-lint pass 3: wire-protocol conformance.

Handlers are functions with ``op = msg.get("op")`` / ``msg["op"]``
dispatch chains (or inline ``header.get("op") == "put"`` tests); for
each op branch we record which envelope fields the handler *requires*
(``msg["field"]``), which it treats as optional (``msg.get(...)``) and
the literal reply dicts it returns.  Client sites are ``_request`` /
``_rpc`` calls carrying a dict payload with an ``"op"`` key (either a
dict literal argument, or a local variable assembled from a dict
literal plus ``var["k"] = ...`` updates).

Batch sub-ops are wire frames too: a dict literal carrying a constant
``"op"`` key that is queued for a later ``batch`` frame (via
``.append(...)``/``.extend(...)``) or written inline in the list under
an ``"ops"`` key is cross-checked exactly like a top-level client send
-- a malformed sub-op must fail lint here, not at dispatch time.

Multi-blob push frames get the same treatment on the blob plane: a
frame with a constant ``"op"`` and a ``"blobs"`` key carries per-blob
declaration dicts, each cross-checked under the pseudo-op
``"<op>#blob"``. The matching handler is the ``for``-loop over the
frame's ``"blobs"`` list -- inline in the op branch, or in a helper
the branch calls (one level deep, the BlobServer delegation shape).
A declared blob no handler loop ever reads is SYN-W001; a per-blob
field the loop requires that no declaration carries is SYN-W002.

Metric-delta frames (``DELTA_OPS``) go one level deeper: every payload
field a client ships is cross-checked as pseudo-op ``"<op>#<field>"``
against the envelope fields the op's handler actually reads --
directly in the branch, or in a helper the branch passes the whole
message to (one level deep, the ``_handle_metric_deltas`` delegation
shape). A metric payload the workers export that the head never folds
into its aggregates is SYN-W001 -- silently dropped telemetry fails
CI, it does not page an operator with a frozen graph.

SYN-W001  op sent by a client but matched by no handler branch.
SYN-W002  field a handler requires that no client site for that op
          ever sends (ops never sent in the analyzed tree are skipped:
          they belong to out-of-tree callers such as operator tooling).
SYN-W003  literal reply dict with neither ``ok`` nor ``error``.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.model import CodeModel, Finding

CLIENT_CALL_NAMES = {"_request", "_rpc"}

#: list mutators that queue a sub-op for a later `batch` frame
BATCH_QUEUE_METHODS = {"append", "extend"}

#: data-plane delta ops whose payload fields are each cross-checked as
#: pseudo-op ``"<op>#<field>"`` -- the exported-but-never-aggregated
#: detector for telemetry riding the batch frame
DELTA_OPS = {"metric_deltas"}


@dataclass
class HandlerInfo:
    op: str
    file: str
    function: str
    line: int
    required: Dict[str, int] = field(default_factory=dict)  # field->line
    optional: Set[str] = field(default_factory=set)
    replies: List[Tuple[int, Set[str]]] = field(default_factory=list)


@dataclass
class SendSite:
    op: str
    file: str
    function: str
    line: int
    keys: Set[str] = field(default_factory=set)


def check_wire(model: CodeModel) -> List[Finding]:
    handlers: Dict[str, List[HandlerInfo]] = {}
    sends: List[SendSite] = []
    # helpers that iterate a frame's "blobs" declarations, keyed by
    # bare name: an op branch that calls one adopts its per-blob reads
    blob_loop_fns: Dict[str, Tuple[object, Tuple[Dict[str, int],
                                                 Set[str], int]]] = {}
    for fn in model.functions.values():
        bf = _blob_entry_fields(fn.node.body)
        if bf is not None:
            blob_loop_fns[fn.qualname.split(".")[-1]] = (fn, bf)
    # helpers a DELTA_OPS branch hands the whole message to, keyed by
    # bare name: the branch adopts the helper's envelope-field reads
    delta_helper_fns: Dict[str, Tuple[object, Tuple[Dict[str, int],
                                                    Set[str], int]]] = {}
    for fn in model.functions.values():
        pf = _param_field_reads(fn)
        if pf is not None:
            delta_helper_fns[fn.qualname.split(".")[-1]] = (fn, pf)
    for fn in model.functions.values():
        for h in _extract_handlers(fn):
            handlers.setdefault(h.op, []).append(h)
        for h in _extract_blob_handlers(fn, blob_loop_fns):
            handlers.setdefault(h.op, []).append(h)
        for h in _extract_delta_handlers(fn, delta_helper_fns):
            handlers.setdefault(h.op, []).append(h)
        sends.extend(_extract_sends(fn))
        sends.extend(_extract_batch_subops(fn))
        sends.extend(_extract_blob_subops(fn))

    # delta frames: every payload field a client ships becomes a
    # pseudo-op send, so a metric field with no head-side fold is a
    # missing-handler finding at the site that exports it
    for s in list(sends):
        if s.op in DELTA_OPS:
            for fld in sorted(s.keys - {"op"}):
                sends.append(SendSite(op=f"{s.op}#{fld}", file=s.file,
                                      function=s.function, line=s.line,
                                      keys=set(s.keys)))

    findings: List[Finding] = []
    for s in sends:
        if s.op not in handlers:
            findings.append(Finding(
                "SYN-W001", s.file, s.line, s.function,
                f"op {s.op!r} sent but no handler branch matches"))

    sent_keys: Dict[str, Set[str]] = {}
    for s in sends:
        sent_keys.setdefault(s.op, set()).update(s.keys)
    for op, hs in handlers.items():
        if op not in sent_keys:
            continue  # only out-of-tree callers (operator ops)
        for h in hs:
            for fld, line in sorted(h.required.items()):
                if fld not in sent_keys[op]:
                    findings.append(Finding(
                        "SYN-W002", h.file, line, h.function,
                        f"handler for op {op!r} requires field "
                        f"{fld!r} never sent by any call site"))

    for hs in handlers.values():
        for h in hs:
            for line, keys in h.replies:
                if not keys & {"ok", "error"}:
                    findings.append(Finding(
                        "SYN-W003", h.file, line, h.function,
                        f"reply for op {h.op!r} has neither 'ok' nor "
                        f"'error' key"))
    return findings


# -- handler extraction ---------------------------------------------------


def _const_str(e: ast.AST) -> Optional[str]:
    if isinstance(e, ast.Constant) and isinstance(e.value, str):
        return e.value
    return None


def _reads_field(e: ast.AST) -> Optional[Tuple[str, str]]:
    """(msg var, field) for ``var["field"]`` or ``var.get("field")``."""
    if (isinstance(e, ast.Subscript)
            and isinstance(e.value, ast.Name)):
        fld = _const_str(e.slice)
        if fld is not None:
            return e.value.id, fld
    return None


def _op_read_var(e: ast.AST) -> Optional[str]:
    """msg var name when e is ``var.get("op")`` or ``var["op"]``."""
    if (isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute)
            and e.func.attr == "get" and e.args
            and isinstance(e.func.value, ast.Name)
            and _const_str(e.args[0]) == "op"):
        return e.func.value.id
    rf = _reads_field(e)
    if rf and rf[1] == "op":
        return rf[0]
    return None


def _branch_ops(test: ast.AST,
                opvars: Dict[str, str]) -> Optional[Tuple[str, List[str]]]:
    """(msg var, [ops]) when `test` compares an op against literals."""
    if not (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.ops[0], (ast.Eq, ast.In))):
        return None
    left = test.left
    msgvar = None
    if isinstance(left, ast.Name) and left.id in opvars:
        msgvar = opvars[left.id]
    else:
        msgvar = _op_read_var(left)
    if msgvar is None:
        return None
    cmp = test.comparators[0]
    ops: List[str] = []
    if isinstance(cmp, (ast.Tuple, ast.List, ast.Set)):
        for el in cmp.elts:
            v = _const_str(el)
            if v is not None:
                ops.append(v)
    else:
        v = _const_str(cmp)
        if v is not None:
            ops.append(v)
    return (msgvar, ops) if ops else None


def _reply_dicts(value: ast.AST) -> List[ast.Dict]:
    if isinstance(value, ast.Dict):
        return [value]
    if (isinstance(value, ast.Tuple) and value.elts
            and isinstance(value.elts[0], ast.Dict)):
        return [value.elts[0]]
    if isinstance(value, ast.Call):
        return [a for a in value.args if isinstance(a, ast.Dict)]
    return []


def _dict_keys(d: ast.Dict) -> Optional[Set[str]]:
    """Constant keys, or None when unknowable (** splat / computed)."""
    keys: Set[str] = set()
    for k in d.keys:
        if k is None:
            return None
        v = _const_str(k)
        if v is None:
            return None
        keys.add(v)
    return keys


def _extract_handlers(fn) -> List[HandlerInfo]:
    node = fn.node
    opvars: Dict[str, str] = {}  # op var name -> msg var name
    for st in ast.walk(node):
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)):
            mv = _op_read_var(st.value)
            if mv:
                opvars[st.targets[0].id] = mv
    out: List[HandlerInfo] = []
    for st in ast.walk(node):
        if not isinstance(st, ast.If):
            continue
        hit = _branch_ops(st.test, opvars)
        if not hit:
            continue
        msgvar, ops = hit
        for op in ops:
            info = HandlerInfo(op=op, file=fn.file,
                               function=fn.qualname, line=st.lineno)
            _collect_branch(info, st.body, msgvar)
            out.append(info)
    return out


def _collect_branch(info: HandlerInfo, stmts: List[ast.stmt],
                    msgvar: str) -> None:
    for st in stmts:
        for n in ast.walk(st):
            rf = _reads_field(n)
            if rf and rf[0] == msgvar and rf[1] != "op":
                info.required.setdefault(rf[1], n.lineno)
            if (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "get" and n.args
                    and isinstance(n.func.value, ast.Name)
                    and n.func.value.id == msgvar):
                fld = _const_str(n.args[0])
                if fld and fld != "op":
                    info.optional.add(fld)
            if isinstance(n, ast.Return) and n.value is not None:
                for d in _reply_dicts(n.value):
                    keys = _dict_keys(d)
                    if keys is not None:
                        info.replies.append((d.lineno, keys))


# -- multi-blob frame extraction ------------------------------------------


def _is_blobs_read(e: ast.AST) -> bool:
    """``var.get("blobs")`` or ``var["blobs"]``."""
    if (isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute)
            and e.func.attr == "get" and e.args
            and _const_str(e.args[0]) == "blobs"):
        return True
    rf = _reads_field(e)
    return rf is not None and rf[1] == "blobs"


def _strip_or(e: ast.AST) -> ast.AST:
    """Unwrap the ``x or []`` default idiom to the real source."""
    if isinstance(e, ast.BoolOp) and isinstance(e.op, ast.Or) and e.values:
        return e.values[0]
    return e


def _blob_entry_fields(stmts: List[ast.stmt]
                       ) -> Optional[Tuple[Dict[str, int], Set[str], int]]:
    """(required, optional, line) of per-blob field reads when `stmts`
    loop over a frame's ``"blobs"`` list -- directly, via a local alias,
    or as the first argument of a ``zip(...)``; None when they don't."""
    blob_vars: Set[str] = set()
    for st in stmts:
        for n in ast.walk(st):
            if (isinstance(n, ast.Assign) and len(n.targets) == 1
                    and isinstance(n.targets[0], ast.Name)
                    and _is_blobs_read(_strip_or(n.value))):
                blob_vars.add(n.targets[0].id)
    required: Dict[str, int] = {}
    optional: Set[str] = set()
    line: Optional[int] = None
    for st in stmts:
        for n in ast.walk(st):
            if not isinstance(n, ast.For):
                continue
            it, tgt = n.iter, n.target
            if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                    and it.func.id == "zip" and it.args):
                it = it.args[0]
                if isinstance(tgt, ast.Tuple) and tgt.elts:
                    tgt = tgt.elts[0]
            it = _strip_or(it)
            if not (_is_blobs_read(it)
                    or (isinstance(it, ast.Name) and it.id in blob_vars)):
                continue
            if not isinstance(tgt, ast.Name):
                continue
            entry = tgt.id
            if line is None:
                line = n.lineno
            for m in ast.walk(n):
                rf = _reads_field(m)
                if rf and rf[0] == entry:
                    required.setdefault(rf[1], m.lineno)
                if (isinstance(m, ast.Call)
                        and isinstance(m.func, ast.Attribute)
                        and m.func.attr == "get" and m.args
                        and isinstance(m.func.value, ast.Name)
                        and m.func.value.id == entry):
                    fld = _const_str(m.args[0])
                    if fld:
                        optional.add(fld)
    if line is None:
        return None
    return required, optional, line


def _extract_blob_handlers(fn, blob_loop_fns) -> List[HandlerInfo]:
    """Pseudo-op ``"<op>#blob"`` handlers: op branches that loop over the
    frame's ``"blobs"`` declarations inline, or call a helper that does
    (one level deep -- the BlobServer shape, where the branch delegates
    to ``_verify_batch``/``_put_batch``)."""
    node = fn.node
    opvars: Dict[str, str] = {}
    for st in ast.walk(node):
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)):
            mv = _op_read_var(st.value)
            if mv:
                opvars[st.targets[0].id] = mv
    out: List[HandlerInfo] = []
    for st in ast.walk(node):
        if not isinstance(st, ast.If):
            continue
        hit = _branch_ops(st.test, opvars)
        if not hit:
            continue
        _msgvar, ops = hit
        hits: List[Tuple[object, Tuple[Dict[str, int], Set[str], int]]] = []
        inline = _blob_entry_fields(st.body)
        if inline is not None:
            hits.append((fn, inline))
        seen = {id(fn)} if inline is not None else set()
        for b in st.body:
            for n in ast.walk(b):
                if not isinstance(n, ast.Call):
                    continue
                cname = None
                if isinstance(n.func, ast.Name):
                    cname = n.func.id
                elif isinstance(n.func, ast.Attribute):
                    cname = n.func.attr
                tgt = blob_loop_fns.get(cname)
                if tgt is not None and id(tgt[0]) not in seen:
                    seen.add(id(tgt[0]))
                    hits.append(tgt)
        for op in ops:
            for hfn, (req, opt, line) in hits:
                out.append(HandlerInfo(
                    op=f"{op}#blob", file=hfn.file,
                    function=hfn.qualname, line=line,
                    required=dict(req), optional=set(opt)))
    return out


def _extract_blob_subops(fn) -> List[SendSite]:
    """Send sites hiding inside multi-blob push frames: a dict literal
    with a constant ``"op"`` and a ``"blobs"`` key is a blob-plane
    frame, and each per-blob declaration dict under ``"blobs"`` (inline,
    or via a local list variable such as a comprehension) is
    cross-checked as pseudo-op ``"<op>#blob"``."""
    node = fn.node
    # local list-of-declaration variables (e.g. a list comprehension of
    # per-blob dicts): var -> the dict literals it was built from
    local_lists: Dict[str, List[ast.Dict]] = {}
    for st in ast.walk(node):
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)
                and not isinstance(st.value, ast.Dict)):
            dicts = [d for d in ast.walk(st.value)
                     if isinstance(d, ast.Dict)]
            if dicts:
                local_lists[st.targets[0].id] = dicts
    out: List[SendSite] = []
    for n in ast.walk(node):
        if not isinstance(n, ast.Dict):
            continue
        op: Optional[str] = None
        blobs_val: Optional[ast.AST] = None
        for k, v in zip(n.keys, n.values):
            ks = _const_str(k) if k is not None else None
            if ks == "op":
                op = _const_str(v)
            elif ks == "blobs":
                blobs_val = v
        if op is None or blobs_val is None:
            continue
        if isinstance(blobs_val, ast.Name):
            decls = local_lists.get(blobs_val.id, [])
        else:
            decls = [d for d in ast.walk(blobs_val)
                     if isinstance(d, ast.Dict)]
        for bd in decls:
            keys = _dict_keys(bd)
            if keys is not None:
                out.append(SendSite(op=f"{op}#blob", file=fn.file,
                                    function=fn.qualname, line=bd.lineno,
                                    keys=keys))
    return out


# -- metric-delta frame extraction ----------------------------------------


def _param_field_reads(fn) -> Optional[Tuple[Dict[str, int],
                                             Set[str], int]]:
    """(required, optional, line) of envelope-field reads a function
    performs on its FIRST non-self parameter; None when it has no such
    parameter or never reads a field off it. This is how a dispatch
    branch that hands the whole message to a helper
    (``self._handle_metric_deltas(msg)``) adopts the helper's reads."""
    names = [a.arg for a in fn.node.args.args if a.arg not in ("self",
                                                               "cls")]
    if not names:
        return None
    probe = HandlerInfo(op="", file=fn.file, function=fn.qualname,
                        line=fn.node.lineno)
    _collect_branch(probe, fn.node.body, names[0])
    if not probe.required and not probe.optional:
        return None
    return dict(probe.required), set(probe.optional), fn.node.lineno


def _extract_delta_handlers(fn, delta_helper_fns) -> List[HandlerInfo]:
    """Pseudo-op ``"<op>#<field>"`` handlers for DELTA_OPS branches:
    every envelope field the branch reads -- directly, or in a helper
    it passes the whole message to (one level deep, the
    ``_handle_metric_deltas`` delegation shape) -- counts as folded.
    The helper's reads also back an extra base-op handler entry, so a
    field the helper *requires* that no client ships stays SYN-W002
    even through the delegation."""
    node = fn.node
    opvars: Dict[str, str] = {}
    for st in ast.walk(node):
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)):
            mv = _op_read_var(st.value)
            if mv:
                opvars[st.targets[0].id] = mv
    out: List[HandlerInfo] = []
    for st in ast.walk(node):
        if not isinstance(st, ast.If):
            continue
        hit = _branch_ops(st.test, opvars)
        if not hit:
            continue
        msgvar, ops = hit
        ops = [op for op in ops if op in DELTA_OPS]
        if not ops:
            continue
        probe = HandlerInfo(op="", file=fn.file, function=fn.qualname,
                            line=st.lineno)
        _collect_branch(probe, st.body, msgvar)
        required, optional = dict(probe.required), set(probe.optional)
        helper_hits: List[Tuple[object, Tuple[Dict[str, int],
                                              Set[str], int]]] = []
        seen: Set[int] = set()
        for b in st.body:
            for n in ast.walk(b):
                if not isinstance(n, ast.Call):
                    continue
                if not any(isinstance(a, ast.Name) and a.id == msgvar
                           for a in n.args):
                    continue
                cname = None
                if isinstance(n.func, ast.Name):
                    cname = n.func.id
                elif isinstance(n.func, ast.Attribute):
                    cname = n.func.attr
                tgt = delta_helper_fns.get(cname)
                if tgt is not None and id(tgt[0]) not in seen:
                    seen.add(id(tgt[0]))
                    helper_hits.append(tgt)
        for _hfn, (hreq, hopt, _hline) in helper_hits:
            for fld, line in hreq.items():
                required.setdefault(fld, line)
            optional |= hopt
        for op in ops:
            for fld, line in sorted(required.items()):
                out.append(HandlerInfo(
                    op=f"{op}#{fld}", file=fn.file, function=fn.qualname,
                    line=line, required={fld: line}))
            for fld in sorted(optional - set(required)):
                out.append(HandlerInfo(
                    op=f"{op}#{fld}", file=fn.file, function=fn.qualname,
                    line=st.lineno, optional={fld}))
            for hfn, (hreq, hopt, hline) in helper_hits:
                out.append(HandlerInfo(
                    op=op, file=hfn.file, function=hfn.qualname,
                    line=hline, required=dict(hreq), optional=set(hopt)))
    return out


# -- client-site extraction ----------------------------------------------


def _local_dict_payloads(node) -> Dict[str, Dict[str, Optional[str]]]:
    """Local dict payloads assembled in `node`: var -> constant key map
    (a dict-literal assignment -- plain or annotated -- plus later
    ``var["k"] = ...`` updates, order-insensitive on purpose: a key set
    on any path counts as carried)."""
    local_dicts: Dict[str, Dict[str, Optional[str]]] = {}
    for st in ast.walk(node):
        if isinstance(st, ast.Assign) and len(st.targets) == 1:
            tgt, value = st.targets[0], st.value
        elif isinstance(st, ast.AnnAssign) and st.value is not None:
            tgt, value = st.target, st.value
        else:
            continue
        if isinstance(tgt, ast.Name) and isinstance(value, ast.Dict):
            keys = _dict_keys(value)
            if keys is None:
                continue
            kv: Dict[str, Optional[str]] = {k: None for k in keys}
            for k, v in zip(value.keys, value.values):
                kv[_const_str(k)] = _const_str(v)
            local_dicts.setdefault(tgt.id, {}).update(kv)
        elif (isinstance(tgt, ast.Subscript)
              and isinstance(tgt.value, ast.Name)
              and tgt.value.id in local_dicts):
            fld = _const_str(tgt.slice)
            if fld is not None:
                local_dicts[tgt.value.id][fld] = _const_str(value)
    return local_dicts


def _extract_batch_subops(fn) -> List[SendSite]:
    """Send sites hiding inside `batch` frames: dict literals with a
    constant ``"op"`` key that are (a) queued through a list's
    ``.append``/``.extend`` for a later batch (the worker's pending-ack
    queue pattern, and the head's actor-directive outbox) or (b) written
    inline in the list under an ``"ops"`` or ``"actor_ops"`` key (the
    poll reply's piggybacked actor directives). Each becomes an ordinary
    SendSite so SYN-W001/W002 hold for sub-ops exactly as for top-level
    frames. A queued *variable* resolves through the local payload map
    (the worker assembles its metric-delta sub-op field by field before
    ``ops.append(sub)`` -- that is a send site too)."""
    out: List[SendSite] = []
    local_dicts = _local_dict_payloads(fn.node)

    def emit(d: ast.Dict):
        keys = _dict_keys(d)
        if keys is None or "op" not in keys:
            return
        op = None
        for k, v in zip(d.keys, d.values):
            if _const_str(k) == "op":
                op = _const_str(v)
        if op is None:
            return                 # dynamic sub-op name: nothing to check
        out.append(SendSite(op=op, file=fn.file, function=fn.qualname,
                            line=d.lineno, keys=keys))

    def emit_name(name: str, line: int):
        payload = local_dicts.get(name)
        if payload is None or payload.get("op") is None:
            return
        out.append(SendSite(op=payload["op"], file=fn.file,
                            function=fn.qualname, line=line,
                            keys=set(payload)))

    for n in ast.walk(fn.node):
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                and n.func.attr in BATCH_QUEUE_METHODS):
            for a in n.args:
                for d in ast.walk(a):
                    if isinstance(d, ast.Dict):
                        emit(d)
                    elif isinstance(d, ast.Name):
                        emit_name(d.id, n.lineno)
        elif isinstance(n, ast.Dict):
            for k, v in zip(n.keys, n.values):
                if k is not None and _const_str(k) in ("ops", "actor_ops"):
                    for d in ast.walk(v):
                        if isinstance(d, ast.Dict):
                            emit(d)
    return out


def _extract_sends(fn) -> List[SendSite]:
    node = fn.node
    local_dicts = _local_dict_payloads(node)
    out: List[SendSite] = []
    for n in ast.walk(node):
        if not isinstance(n, ast.Call):
            continue
        cname = None
        if isinstance(n.func, ast.Name):
            cname = n.func.id
        elif isinstance(n.func, ast.Attribute):
            cname = n.func.attr
        if cname not in CLIENT_CALL_NAMES:
            continue
        for a in list(n.args) + [k.value for k in n.keywords]:
            payload: Optional[Dict[str, Optional[str]]] = None
            if isinstance(a, ast.Dict):
                keys = _dict_keys(a)
                if keys is not None and "op" in keys:
                    payload = {k: None for k in keys}
                    for k, v in zip(a.keys, a.values):
                        payload[_const_str(k)] = _const_str(v)
            elif (isinstance(a, ast.Name)
                  and a.id in local_dicts
                  and "op" in local_dicts[a.id]):
                payload = local_dicts[a.id]
            if payload is None:
                continue
            op = payload.get("op")
            if op is None:
                continue  # dynamic op name: nothing to check
            out.append(SendSite(op=op, file=fn.file,
                                function=fn.qualname, line=n.lineno,
                                keys=set(payload)))
    return out
