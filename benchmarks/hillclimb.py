"""§Perf hillclimb driver: run tagged dry-run variants of the three chosen
cells and print before/after roofline terms.

Cells (chosen per spec: worst roofline fraction / most collective-bound /
most representative):
  * arctic-480b  x train_4k   -- worst cell (over-memory, biggest model)
  * llama3-8b    x train_4k   -- representative dense training
  * qwen1.5-32b  x decode_32k -- serving cell (Syndeo's fleet workload)

Each iteration is cumulative (it2 includes it1, ...). The paper-faithful
baseline lives under tag "baseline" and is never overwritten.
"""
from __future__ import annotations

import json
import sys

sys.path.insert(0, "src")

ITERATIONS = [
    # (arch, shape, tag, overrides)
    ("llama3-8b", "train_4k", "it1_flashvjp", {"flash_vjp": True}),
    ("llama3-8b", "train_4k", "it2_sp",
     {"flash_vjp": True, "rules": {"seq": ("model",)}}),
    ("arctic-480b", "train_4k", "it1_flashvjp", {"flash_vjp": True}),
    ("arctic-480b", "train_4k", "it2_sp",
     {"flash_vjp": True, "rules": {"seq": ("model",)}}),
    ("arctic-480b", "train_4k", "it3_bf16accum",
     {"flash_vjp": True, "rules": {"seq": ("model",)},
      "accum_dtype": "bfloat16"}),
    ("qwen1.5-32b", "decode_32k", "it1_bf16dequant",
     {"dequant_dtype": "bfloat16"}),
    ("qwen1.5-32b", "decode_32k", "it2_blocks",
     {"dequant_dtype": "bfloat16", "decode_block_k": 2048}),
]


def main():
    from repro.launch.dryrun import run_cell
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for arch, shape, tag, ov in ITERATIONS:
        if only and only not in (arch, tag):
            continue
        rec = run_cell(arch, shape, multi_pod=False, force=True,
                       overrides=ov, tag=tag)
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"  -> {tag}: c={r['compute_s']:.3e} m={r['memory_s']:.3e} "
                  f"x={r['collective_s']:.3e} frac={r['roofline_fraction']:.3f} "
                  f"mem={rec['memory']['peak_per_device_gb']:.1f}GiB")


if __name__ == "__main__":
    main()
