"""Fixture: the repaired twin of wire_blobs_bad.py.

``push_many`` clients now declare the ``priority`` field the handler's
blob loop requires, and ``drop_many`` actually iterates its
declarations -- both pseudo-ops (``push_many#blob``, ``drop_many#blob``)
line up client-to-handler, so the file must lint clean.
"""


class Server:
    def dispatch(self, msg):
        op = msg.get("op")
        if op == "push_many":
            total = 0
            for b in msg["blobs"]:
                total += b["priority"]
            return {"ok": True, "total": total}
        if op == "drop_many":
            count = 0
            for b in msg.get("blobs") or []:
                if b.get("object"):
                    count += 1
            return {"ok": True, "count": count}
        return {"ok": False, "error": f"unknown op {op!r}"}


def push_all(_request, host, port, token, items):
    frame = {"op": "push_many",
             "blobs": [{"object": o, "size": n, "priority": 0}
                       for o, n in items]}
    return _request(host, port, token, frame)


def drop_all(_request, host, port, token, items):
    frame = {"op": "drop_many",
             "blobs": [{"object": o, "size": n} for o, n in items]}
    return _request(host, port, token, frame)
