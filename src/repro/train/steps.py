"""train_step / serve_step factories.

train_step: microbatched gradient accumulation (scan over microbatches,
fp32 accumulators), gradient clipping, optimizer update. Loss/grads are
computed under the model's remat policy; GSPMD inserts the DP gradient
reduce inside the accumulation loop, overlapping compute with communication.

serve_step: prefill (full forward + KV cache materialization) and decode
(one token against the cache) -- these are the artifacts lowered by the
decode_*/long_* dry-run cells.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim.optimizers import Optimizer, clip_by_global_norm

F32 = jnp.float32


def make_train_step(model: Model, opt: Optimizer,
                    lr_fn: Callable[[Any], Any],
                    n_microbatches: int = 1,
                    clip_norm: float = 1.0,
                    grad_shardings: Any = None,
                    accum_dtype: str = "float32"):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "step"}; batch leaves lead with global batch.
    grad_shardings (optional pytree of NamedSharding mirroring params): the
    fp32 gradient accumulator is constrained to it -- pass ZeRO-1-extended
    param shardings to get ZeRO-2-style DP-sharded accumulation (each
    microbatch's gradient reduce becomes a reduce-scatter, overlapping the
    backward compute; saves (dp-1)/dp of the fp32 accumulator memory).
    """

    ACC = jnp.dtype(accum_dtype)

    def _constrain_grads(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s) if s is not None else g,
            tree, grad_shardings)

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, mb)
        return loss, grads

    def train_step(state, batch):
        params = state["params"]

        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
            grads = _constrain_grads(jax.tree.map(lambda g: g.astype(ACC), grads))
        else:
            def split_mb(x):
                b = x.shape[0]
                assert b % n_microbatches == 0, (b, n_microbatches)
                return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

            mbs = jax.tree.map(split_mb, batch)
            acc0 = _constrain_grads(
                jax.tree.map(lambda p: jnp.zeros(p.shape, ACC), params))

            def body(carry, mb):
                acc, loss_acc = carry
                loss, grads = grads_of(params, mb)
                acc = _constrain_grads(jax.tree.map(
                    lambda a, g: a + g.astype(ACC), acc, grads))
                return (acc, loss_acc + loss), None

            (gsum, loss_sum), _ = jax.lax.scan(body, (acc0, jnp.zeros((), F32)), mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = loss_sum / n_microbatches

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state["step"])
        new_params, new_opt, stats = opt.update(params, grads, state["opt"], lr)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_init_state(model: Model, opt: Optimizer):
    def init_state(key):
        params = model.init_params(key)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}
    return init_state


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch)
        # greedy next token (serving engine may re-sample)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, cache
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, batch):
        logits, cache = model.decode_step(params, cache, batch)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, cache
    return decode_step
