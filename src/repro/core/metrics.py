"""Typed metric instruments + the cluster metrics pipeline.

The observability plane (ROADMAP item 3) in one module:

  * `Counter` / `Gauge` / `Histogram` -- the three instrument kinds.
    Histograms use FIXED log-spaced bucket bounds with mergeable state
    (per-bucket counts + sum + count), so worker-side observations fold
    into head-side aggregates by pure element-wise addition: merge is
    associative and commutative (property-tested in
    tests/test_observability.py), and a wire delta is just the counts
    that changed since the last confirmed send.
  * `MetricsRegistry` -- instruments keyed by (name, labels). The
    scheduler owns one; the head's `MetricsHub` shares it so sojourn
    histograms, worker-folded histograms and router gauges land in one
    place.
  * `TimeSeries` / `MetricsHub` -- head-side ring-buffer history keyed
    by (metric, label): every `metrics` op snapshot is recorded, so
    dashboards get history without a second collection path.
  * `render_prometheus` -- Prometheus text exposition format (label
    escaping, `_bucket`/`_sum`/`_count` layout, `+Inf`), golden-tested.
  * `render_dashboards` -- Grafana-style dashboard JSON for the four
    boards operators actually watch: serve, drain, dataplane, tenancy.
  * `build_cluster_metrics` -- the ONE builder that turns ground truth
    (store.stats, scheduler stats/registry, worker delta aggregates,
    router-fed serve gauges) into the flat `metrics`-op reply. The head
    and `SimCluster.export_metrics` both call it, and the chaos
    conformance checker (tests/_invariants.py) asserts its output
    against the raw sources -- metrics that disagree with reality are a
    test failure, not a dashboard surprise.

Quantile estimates are bucket-bounded: `Histogram.quantile(q)` returns
the upper bound of the bucket holding the q-th order statistic, so the
estimate is never below the exact sample and never more than one bucket
above it.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


def log_buckets(lo: float, hi: float, factor: float = 2.0) -> Tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds: lo, lo*factor, ... >= hi.
    Fixed (not adaptive) so every producer of a histogram name shares
    the same bounds and merge stays a pure element-wise add."""
    assert lo > 0 and factor > 1.0 and hi >= lo
    out = [float(lo)]
    while out[-1] < hi:
        out.append(out[-1] * factor)
    return tuple(out)


# well-known bounds: seconds (1ms .. ~1.1h), queue depths, byte sizes.
# Wire deltas carry bucket indices only, so the sender and the head MUST
# agree on bounds per histogram name -- register new names here.
TIME_BUCKETS = log_buckets(0.001, 4096.0)
DEPTH_BUCKETS = log_buckets(0.25, 4096.0)
SIZE_BUCKETS = log_buckets(256.0, float(1 << 32), factor=4.0)

BOUNDS_BY_NAME: Dict[str, Tuple[float, ...]] = {
    "syndeo_task_sojourn_seconds": TIME_BUCKETS,
    "syndeo_worker_poll_seconds": TIME_BUCKETS,
    "syndeo_router_queue_depth": DEPTH_BUCKETS,
    "syndeo_router_shed_depth": DEPTH_BUCKETS,
}


class Counter:
    """Monotone counter. `inc` only; exported value is `.value`."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1):
        assert n >= 0, "counters are monotone"
        self.value += n


class Gauge:
    """Point-in-time value; `set` replaces, `add` adjusts."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def add(self, dv: float):
        self.value += float(dv)


class Histogram:
    """Fixed-bound log-bucket histogram with mergeable state.

    `counts[i]` counts observations v with v <= bounds[i] (and
    > bounds[i-1]); `counts[-1]` is the overflow bucket. State is
    (counts, sum, count) -- element-wise addable, so merge is
    associative and commutative and a wire delta is sparse counts plus
    scalar sum/count deltas."""

    __slots__ = ("bounds", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, bounds: Tuple[float, ...] = TIME_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        assert list(self.bounds) == sorted(set(self.bounds)), \
            "histogram bounds must be strictly increasing"
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def bucket_index(self, v: float) -> int:
        return bisect.bisect_left(self.bounds, float(v))

    def observe(self, v: float):
        self.counts[self.bucket_index(v)] += 1
        self.sum += float(v)
        self.count += 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Pure merge: a NEW histogram holding both states (the
        associativity/commutativity property the tests pin)."""
        assert self.bounds == other.bounds, "cannot merge mismatched bounds"
        out = Histogram(self.bounds)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.sum = self.sum + other.sum
        out.count = self.count + other.count
        return out

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Histogram):
            return NotImplemented
        return (self.bounds == other.bounds
                and self.counts == other.counts
                and self.count == other.count
                and math.isclose(self.sum, other.sum,
                                 rel_tol=1e-9, abs_tol=1e-9))

    def __hash__(self):  # pragma: no cover -- dict-key use is a bug
        raise TypeError("histograms are mutable; not hashable")

    def quantile(self, q: float) -> float:
        """Bucket-bounded quantile estimate: the upper bound of the
        bucket containing the ceil(q*count)-th order statistic (overflow
        clamps to the top bound). >= the exact order statistic, and at
        most one bucket above it."""
        if self.count <= 0:
            return 0.0
        rank = max(1, math.ceil(min(max(q, 0.0), 1.0) * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bounds[min(i, len(self.bounds) - 1)]
        return self.bounds[-1]

    # -- wire deltas (the worker -> head piggyback path) ----------------------

    def to_delta(self, base: "Histogram") -> Dict[str, Any]:
        """Sparse JSON-safe delta since `base` (the last confirmed
        send): bucket-index -> count delta, plus sum/count deltas."""
        assert self.bounds == base.bounds
        return {"counts": {str(i): a - b
                           for i, (a, b) in enumerate(zip(self.counts,
                                                          base.counts))
                           if a != b},
                "sum": self.sum - base.sum,
                "count": self.count - base.count}

    def apply_delta(self, delta: Dict[str, Any]):
        """Fold a wire delta in (head-side aggregation, and the sender's
        base advance after a confirmed send). Hot path: the head folds
        one of these per worker poll, so skip the zero fields."""
        counts = delta.get("counts")
        if counts:
            cs, n = self.counts, len(self.counts)
            for k, v in counts.items():
                i = int(k)
                if 0 <= i < n:
                    cs[i] += int(v)
        s = delta.get("sum")
        if s:
            self.sum += float(s)
        c = delta.get("count")
        if c:
            self.count += int(c)


_FACTORIES = {"counter": Counter, "gauge": Gauge}


class MetricsRegistry:
    """Instruments keyed by (name, sorted label items). Thread-safe
    lookup; instrument mutation is GIL-atomic dict/int work (the
    threaded head additionally serializes writers under its cluster
    lock)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, Dict[Tuple[Tuple[str, str], ...], Any]] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, str],
             factory: Callable[[], Any]):
        key = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        with self._lock:
            fam = self._families.setdefault(name, {})
            inst = fam.get(key)
            if inst is None:
                inst = fam[key] = factory()
            assert inst.kind == kind, \
                f"metric {name!r} is a {inst.kind}, not a {kind}"
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Tuple[float, ...]] = None,
                  **labels) -> Histogram:
        b = bounds or BOUNDS_BY_NAME.get(name, TIME_BUCKETS)
        return self._get("histogram", name, labels, lambda: Histogram(b))

    def family(self, name: str) -> Dict[Tuple[Tuple[str, str], ...], Any]:
        with self._lock:
            return dict(self._families.get(name, {}))

    def samples(self) -> Iterable[Tuple[str, Dict[str, str], Any]]:
        with self._lock:
            flat = [(name, key, inst)
                    for name, fam in sorted(self._families.items())
                    for key, inst in sorted(fam.items())]
        for name, key, inst in flat:
            yield name, dict(key), inst


class TimeSeries:
    """Fixed-capacity ring buffer of (t, value) points."""

    __slots__ = ("capacity", "_buf", "_next", "_len")

    def __init__(self, capacity: int = 512):
        self.capacity = max(1, int(capacity))
        self._buf: List[Tuple[float, float]] = [(0.0, 0.0)] * self.capacity
        self._next = 0
        self._len = 0

    def record(self, t: float, v: float):
        self._buf[self._next] = (float(t), float(v))
        self._next = (self._next + 1) % self.capacity
        self._len = min(self._len + 1, self.capacity)

    def __len__(self) -> int:
        return self._len

    def points(self) -> List[Tuple[float, float]]:
        if self._len < self.capacity:
            return self._buf[:self._len]
        return self._buf[self._next:] + self._buf[:self._next]

    @property
    def latest(self) -> Optional[Tuple[float, float]]:
        return self._buf[self._next - 1] if self._len else None


class MetricsHub:
    """Head-side aggregation point: one shared registry (histograms the
    workers fold into, the scheduler's sojourn family) plus ring-buffer
    time series keyed by (metric, label) fed from each flat `metrics`
    snapshot -- dashboards read history, the HPA reads the latest."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 capacity: int = 512):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.capacity = capacity
        self.series: Dict[Tuple[str, str], TimeSeries] = {}
        self._lock = threading.Lock()

    def _series(self, name: str, label: str = "") -> TimeSeries:
        with self._lock:
            ts = self.series.get((name, label))
            if ts is None:
                ts = self.series[(name, label)] = TimeSeries(self.capacity)
            return ts

    def ingest(self, now: float, flat: Dict[str, Any]):
        """Record one flat metrics snapshot: scalar values get one
        series; dict-valued metrics (per-tenant shares, per-link bytes,
        per-worker aggregates) get one series per label key."""
        for name, v in flat.items():
            if isinstance(v, bool) or name == "ok":
                continue
            if isinstance(v, (int, float)):
                self._series(name).record(now, float(v))
            elif isinstance(v, dict):
                for label, sub in v.items():
                    if isinstance(sub, (int, float)) \
                            and not isinstance(sub, bool):
                        self._series(name, str(label)).record(now, float(sub))

    def history(self, name: str, label: str = "") -> List[Tuple[float, float]]:
        with self._lock:
            ts = self.series.get((name, label))
        return ts.points() if ts is not None else []


# -- Prometheus text exposition ------------------------------------------------

def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return f"{f:.10g}"


def _labels_str(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _sanitize(name: str) -> str:
    return "".join(c if c.isalnum() or c in "_:" else "_" for c in name)


def render_prometheus(registry: Optional[MetricsRegistry] = None,
                      flat: Optional[Dict[str, Any]] = None) -> str:
    """Prometheus text exposition of a registry plus a flat snapshot.

    Registry histograms emit the standard cumulative `_bucket{le=...}`
    series (closing with `le="+Inf"`), `_sum` and `_count`. Flat scalars
    emit as gauges; flat dict-valued metrics emit one sample per entry
    under a `key` label (tenant ids, worker ids, "src->dst" links --
    escaped, since ids are operator-controlled strings)."""
    lines: List[str] = []
    seen_types: set = set()

    def type_line(name: str, kind: str):
        if name not in seen_types:
            seen_types.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for name, labels, inst in (registry.samples() if registry else ()):
        name = _sanitize(name)
        if inst.kind == "histogram":
            type_line(name, "histogram")
            cum = 0
            for i, b in enumerate(inst.bounds):
                cum += inst.counts[i]
                bl = dict(labels, le=_fmt(b))
                lines.append(f"{name}_bucket{_labels_str(bl)} {cum}")
            bl = dict(labels, le="+Inf")
            lines.append(f"{name}_bucket{_labels_str(bl)} {inst.count}")
            lines.append(f"{name}_sum{_labels_str(labels)} {_fmt(inst.sum)}")
            lines.append(f"{name}_count{_labels_str(labels)} {inst.count}")
        else:
            type_line(name, inst.kind)
            lines.append(f"{name}{_labels_str(labels)} {_fmt(inst.value)}")
    for name, v in sorted((flat or {}).items()):
        if isinstance(v, bool) or name == "ok":
            continue
        name = _sanitize(name)
        if isinstance(v, (int, float)):
            type_line(name, "gauge")
            lines.append(f"{name} {_fmt(v)}")
        elif isinstance(v, dict):
            type_line(name, "gauge")
            for label, sub in sorted(v.items()):
                if isinstance(sub, (int, float)) \
                        and not isinstance(sub, bool):
                    ls = _labels_str({"key": str(label)})
                    lines.append(f"{name}{ls} {_fmt(sub)}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[Tuple[str, str], float]:
    """Minimal exposition parser (the conformance checker's read-back
    path): {(metric_name, labels_str): value}. Handles escaped label
    values by keeping the raw label block as the key."""
    out: Dict[Tuple[str, str], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        body, _, val = line.rpartition(" ")
        if "{" in body:
            name, _, rest = body.partition("{")
            labels = "{" + rest
        else:
            name, labels = body, ""
        out[(name, labels)] = (math.inf if val == "+Inf" else float(val))
    return out


# -- Grafana-style dashboard JSON ---------------------------------------------

def _panel(pid: int, title: str, exprs: List[str], x: int, y: int,
           kind: str = "timeseries") -> Dict[str, Any]:
    return {"id": pid, "title": title, "type": kind,
            "datasource": {"type": "prometheus", "uid": "syndeo"},
            "gridPos": {"h": 8, "w": 12, "x": x, "y": y},
            "targets": [{"expr": e, "refId": chr(ord("A") + i)}
                        for i, e in enumerate(exprs)]}


def render_dashboards() -> Dict[str, Dict[str, Any]]:
    """The four boards the planes need watched. Panel exprs reference
    exactly the names `build_cluster_metrics` / `render_prometheus`
    export, so a renamed metric breaks the dashboard test, not the 2am
    page."""
    boards: Dict[str, Dict[str, Any]] = {}

    def board(uid: str, title: str,
              panels: List[Tuple[str, List[str], str]]) -> Dict[str, Any]:
        out = {"uid": f"syndeo-{uid}", "title": title, "tags": ["syndeo"],
               "schemaVersion": 39, "refresh": "10s",
               "time": {"from": "now-1h", "to": "now"},
               "panels": [_panel(i + 1, t, exprs, 12 * (i % 2),
                                 8 * (i // 2), kind)
                          for i, (t, exprs, kind) in enumerate(panels)]}
        boards[uid] = out
        return out

    board("serve", "Syndeo / Serving plane", [
        ("Request rate / shed", ["rate(syndeo_serve_requests[1m])",
                                 "rate(syndeo_serve_shed[1m])"],
         "timeseries"),
        ("p99 latency (ms)", ["syndeo_serve_p99_ms"], "timeseries"),
        ("Live replicas", ["syndeo_replica_count"], "stat"),
        ("Router queue depth",
         ["histogram_quantile(0.99, "
          "rate(syndeo_router_queue_depth_bucket[5m]))"], "timeseries"),
    ])
    board("drain", "Syndeo / Drain plane", [
        ("Moves committed / aborted", ["rate(syndeo_moves_committed[5m])",
                                       "rate(syndeo_moves_aborted[5m])"],
         "timeseries"),
        ("Relay fallbacks", ["rate(syndeo_relay_fallbacks[5m])"],
         "timeseries"),
        ("Head-relayed bytes", ["rate(syndeo_head_relayed_bytes[5m])"],
         "timeseries"),
        ("Drain push bytes (workers)",
         ["rate(syndeo_worker_drain_pushed_bytes[5m])"], "timeseries"),
    ])
    board("dataplane", "Syndeo / Data plane", [
        ("Per-link bytes (top 10)",
         ["topk(10, syndeo_link_bytes)"], "timeseries"),
        ("Worker blob serves / receives",
         ["rate(syndeo_worker_blob_serves[5m])",
          "rate(syndeo_worker_blob_receives[5m])"], "timeseries"),
        ("Broadcast rounds / tree edges / batched moves",
         ["syndeo_broadcast_rounds", "syndeo_tree_edges",
          "syndeo_batched_moves"], "timeseries"),
        ("Spill tier: bytes saved / promotions",
         ["syndeo_delta_spill_bytes_saved", "syndeo_promotions"],
         "timeseries"),
    ])
    board("tenancy", "Syndeo / Tenancy", [
        ("Dominant share by tenant",
         ["syndeo_tenant_dominant_share"], "timeseries"),
        ("Quota pressure by tenant",
         ["syndeo_tenant_quota_fraction"], "timeseries"),
        ("Sojourn p99 by tenant (s)",
         ["syndeo_tenant_sojourn_p99_s"], "timeseries"),
        ("Backlog by tenant", ["backlog_by_tenant"], "timeseries"),
    ])
    return boards


# -- the one metrics builder ---------------------------------------------------

def build_cluster_metrics(store, scheduler,
                          worker_metrics: Optional[Dict[str, Dict[str, int]]]
                          = None,
                          serve_stats: Optional[Dict[str, float]] = None,
                          replica_count: Optional[int] = None,
                          workers: Optional[int] = None,
                          busy: Optional[int] = None,
                          backlog: Optional[int] = None,
                          backlog_by_tenant: Optional[Dict[str, int]] = None,
                          shares: Optional[Dict[str, float]] = None
                          ) -> Dict[str, Any]:
    """Build the flat cluster-metrics snapshot from ground truth. The
    threaded head passes its lock-snapshotted scheduler values; the
    simulator (single-threaded) lets the defaults read the scheduler
    directly. Every key here is cross-checked against the raw sources by
    `tests/_invariants.check_metrics_conformance` at the end of every
    chaos scenario."""
    from repro.core.task_graph import TaskState
    if workers is None:
        alive = [w for w in scheduler.workers.values() if w.alive]
        workers = len(alive)
        busy = sum(1 for w in alive if w.running)
    if backlog is None:
        backlog = sum(1 for t in scheduler.graph.tasks.values()
                      if t.state in (TaskState.READY, TaskState.PENDING))
    if backlog_by_tenant is None:
        backlog_by_tenant = scheduler.backlog_by_tenant()
    if shares is None:
        shares = scheduler.tenant_shares()
    if replica_count is None:
        replica_count = len(scheduler.actors)
    wm_by_id = {str(k): dict(v)
                for k, v in (worker_metrics or {}).items()}
    wm = list(wm_by_id.values())
    serve = dict(serve_stats or {})
    n = max(workers, 1)
    store_stats = store.stats
    out: Dict[str, Any] = {
        "ok": True, "workers": workers, "busy": busy, "backlog": backlog,
        "syndeo_backlog_per_worker": backlog / n,
        "syndeo_busy_fraction": (busy or 0) / n,
        "backlog_by_tenant": backlog_by_tenant,
        "syndeo_tenant_dominant_share": shares,
        "syndeo_tenant_quota_fraction": {
            t: store.tenant_quota_fraction(t)
            for t in sorted(set(shares) | store.quota_tenants())},
        # per-worker delta aggregates, exported raw so the conformance
        # checker can hold each worker's aggregate against that worker's
        # own live counters (the lost-delta regression check)
        "per_worker": wm_by_id,
    }
    # drain-plane health counters + data-plane throughput layer (store
    # directory stats; worker-local shares arrive via piggybacked deltas)
    for k in ("moves_started", "moves_committed", "moves_aborted",
              "relay_fallbacks", "head_relayed_bytes", "replica_gc",
              "broadcast_rounds", "tree_edges"):
        out[f"syndeo_{k}"] = int(store_stats.get(k, 0))
    out["syndeo_batched_moves"] = int(store_stats.get("batched_moves", 0)) \
        + sum(m.get("batched_moves", 0) for m in wm)
    spill = store.spill_tier_stats()
    for k in ("delta_spill_bytes_saved", "promotions"):
        out[f"syndeo_{k}"] = spill[k] + sum(m.get(k, 0) for m in wm)
    # worker blob-plane aggregates (p2p bytes that never touch the head)
    for wire_k, src_k in (("worker_blob_serves", "serves"),
                          ("worker_blob_receives", "receives"),
                          ("worker_served_bytes", "served_bytes"),
                          ("worker_drain_pushed_blobs", "drain_pushed_blobs"),
                          ("worker_drain_pushed_bytes", "drain_pushed_bytes")):
        out[f"syndeo_{wire_k}"] = sum(m.get(src_k, 0) for m in wm)
    # per-link flow gauges off the store's byte accounting
    out["syndeo_link_bytes"] = {f"{src}->{dst}": int(v)
                                for (src, dst), v
                                in store.link_snapshot().items()}
    # per-tenant sojourn percentiles (submit -> result) from the
    # scheduler's mergeable histograms
    registry = getattr(scheduler, "metrics", None)
    soj_count: Dict[str, int] = {}
    soj_p50: Dict[str, float] = {}
    soj_p99: Dict[str, float] = {}
    if registry is not None:
        for key, hist in registry.family("syndeo_task_sojourn_seconds"
                                         ).items():
            tenant = dict(key).get("tenant", "default")
            soj_count[tenant] = hist.count
            soj_p50[tenant] = hist.quantile(0.50)
            soj_p99[tenant] = hist.quantile(0.99)
        poll_fam = registry.family("syndeo_worker_poll_seconds")
        polls = None
        for _key, hist in poll_fam.items():
            polls = hist if polls is None else polls.merge(hist)
        out["syndeo_worker_poll_count"] = polls.count if polls else 0
        out["syndeo_worker_poll_p99_s"] = (polls.quantile(0.99)
                                           if polls else 0.0)
    out["syndeo_tenant_sojourn_count"] = soj_count
    out["syndeo_tenant_sojourn_p50_s"] = soj_p50
    out["syndeo_tenant_sojourn_p99_s"] = soj_p99
    # serving-plane gauges (router-fed via stats_sink)
    out["syndeo_serve_requests"] = int(serve.get("requests", 0))
    out["syndeo_serve_shed"] = int(serve.get("shed", 0))
    out["syndeo_serve_p99_ms"] = float(serve.get("p99_ms", 0.0))
    out["syndeo_replica_count"] = int(replica_count)
    return out
