"""Additional coverage: MoE routing invariants, windowed attention decode,
hybrid window cache, roofline term properties, sharding rule guards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.configs.shapes import ShapeConfig
from repro.models import build_model
from repro.models.moe import _dispatch_one_group, capacity, moe_ffn
from repro.models.registry import make_batch


# ---------------------------------------------------------------- MoE routing

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([2, 4, 8]))
def test_moe_dispatch_conservation(seed, e):
    """Property: every kept slot carries exactly one token row; dropped
    tokens contribute zero; combine weights per token sum to <= 1."""
    n, d, k = 32, 16, 2
    cap = 4
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    logits = jax.random.normal(jax.random.fold_in(key, 1), (n, e))
    slots, inv, top_g, gates = _dispatch_one_group(x, logits, k, cap)
    assert slots.shape == (e * cap, d)
    # rows in slots are either zero or exact copies of x rows
    matched = 0
    for r in np.asarray(slots):
        if np.allclose(r, 0.0):
            continue
        assert any(np.allclose(r, xr) for xr in np.asarray(x))
        matched += 1
    assert matched <= n * k
    g = np.asarray(top_g)
    assert np.all(g >= 0) and np.all(g.sum(-1) <= 1.0 + 1e-5)


def test_moe_capacity_drop_is_graceful():
    """With capacity factor << 1, most tokens drop but the layer still
    produces finite output (dropped tokens pass through residual only)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    cfg = cfg.replace(moe=MoEConfig(n_experts=4, top_k=2,
                                    capacity_factor=0.1))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, ShapeConfig("t", "train", 32, 4))
    loss, _ = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss)


def test_moe_groups_equivalence():
    """Routing is per-token, so n_groups must not change the output much
    (identical up to capacity-boundary effects with generous capacity)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    cfg = cfg.replace(moe=MoEConfig(n_experts=4, top_k=2,
                                    capacity_factor=4.0))
    model1 = build_model(cfg, n_groups=1)
    model2 = build_model(cfg, n_groups=2)
    params = model1.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, ShapeConfig("t", "train", 16, 4))
    l1, _ = jax.jit(model1.loss)(params, batch)
    l2, _ = jax.jit(model2.loss)(params, batch)
    assert jnp.allclose(l1, l2, atol=1e-4, rtol=1e-5), (l1, l2)


# ---------------------------------------------------------------- windowed attention

def test_windowed_equals_full_for_large_window():
    from repro.models.layers import flash_attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    full = flash_attention_ref(q, k, v, causal=True, block_q=16, block_k=16)
    win = flash_attention_ref(q, k, v, causal=True, window=64,
                              block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(win),
                               atol=1e-5, rtol=1e-5)


def test_zamba2_long_context_rolling_cache():
    """Windowed decode on the hybrid arch: positions past the window keep
    producing finite logits from the rolling cache."""
    cfg = get_config("zamba2-2.7b", smoke=True)
    model = build_model(cfg, window=cfg.long_context_window)
    params = model.init_params(jax.random.PRNGKey(0))
    W = cfg.long_context_window
    cache = model.init_cache(2, 4 * W)
    assert cache["k"].shape[2] == W     # rolling buffer is window-sized
    pos = jnp.zeros((2,), jnp.int32)
    step = jax.jit(model.decode_step)
    for t in range(2 * W):              # run past the window boundary
        logits, cache = step(params, cache,
                             {"tokens": jnp.full((2, 1), t % 7, jnp.int32),
                              "positions": pos})
        pos = pos + 1
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


# ---------------------------------------------------------------- roofline properties

def test_roofline_fraction_bounds():
    from repro.roofline import CostTotals, roofline_fraction, roofline_terms
    c = CostTotals(flops=197e12, bytes=819e9 / 2,
                   collectives={"all-reduce": [1, 1e9, 25e9]})
    t = roofline_terms(c)
    assert 0.0 <= roofline_fraction(t) <= 1.0
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(0.5)


def test_model_flops_scaling_props():
    from repro.configs.shapes import SHAPES
    from repro.roofline import model_flops
    cfg = get_config("llama3-8b")
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    assert train > prefill > decode > 0
    # train is fwd+bwd over the same tokens => ~3x prefill at equal tokens
    per_tok_train = train / (256 * 4096)
    per_tok_prefill = prefill / (32 * 32768)
    assert 2.0 < per_tok_train / per_tok_prefill < 4.0


# ---------------------------------------------------------------- sharding guards

def test_guard_drops_indivisible_axes():
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding.axes import _guard_divisibility
    mesh = _jax.make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    spec = _guard_divisibility(FakeMesh, (8, 128), P("model", "data"))
    assert spec == P(None, "data")      # 8 kv heads can't split 16 ways
    spec = _guard_divisibility(FakeMesh, (32, 100), P("model", "data"))
    assert spec == P("model", None)     # 100 % 16 != 0


def test_zero1_extends_only_free_dims():
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import zero1_extend
    mesh = _jax.make_mesh((1,), ("x",))

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    out = zero1_extend(P(None, "model"), (4096, 1024), FakeMesh, ("data",))
    assert out == P(("data",), "model")
    # already-used axis is not duplicated
    out = zero1_extend(P("data", "model"), (64, 64), FakeMesh, ("data",))
    assert out == P("data", "model")
