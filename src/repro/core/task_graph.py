"""Tasks and the dependency graph.

A task is an abstraction that starts when its dependencies are met (paper
Fig. 1 right): dependencies are *physical resources* (cpus/tpus on some
worker) and/or *data artifacts* (ObjectRefs in the Global Object Store).
"""
from __future__ import annotations

import time
import uuid
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.object_store import ObjectRef


class TaskState(str, Enum):
    PENDING = "pending"        # waiting on deps
    READY = "ready"            # deps met, waiting for resources
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclass
class TaskSpec:
    fn: Callable[..., Any]
    args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)
    resources: Dict[str, float] = field(default_factory=lambda: {"cpu": 1.0})
    name: str = ""
    # scheduling hints
    group: str = "default"          # straggler stats are tracked per group
    max_retries: int = 3
    placement_group: Optional[str] = None
    bundle_index: Optional[int] = None
    # multi-tenancy: the principal this task runs (and is billed) as --
    # fair-share dispatch, object ownership, and quota accounting key on it
    tenant_id: str = "default"


@dataclass
class Task:
    spec: TaskSpec
    id: str = field(default_factory=lambda: uuid.uuid4().hex)
    state: TaskState = TaskState.PENDING
    deps: List[ObjectRef] = field(default_factory=list)
    output: Optional[ObjectRef] = None
    worker: Optional[str] = None
    attempts: int = 0
    submitted_at: float = field(default_factory=time.monotonic)
    # submission instant on the *scheduler's* clock (virtual time in the
    # simulator): sojourn = finished_at - submitted_clock is coherent,
    # while submitted_at (wall monotonic, used for FIFO ordering) is not
    submitted_clock: Optional[float] = None
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    error: Optional[str] = None
    # speculative re-execution bookkeeping
    speculative_of: Optional[str] = None
    speculated: bool = False

    @property
    def runtime(self) -> Optional[float]:
        if self.started_at is None:
            return None
        end = self.finished_at or time.monotonic()
        return end - self.started_at


class TaskGraph:
    """Dependency bookkeeping: object -> waiting tasks, task -> output."""

    def __init__(self):
        self.tasks: Dict[str, Task] = {}
        self._waiting_on: Dict[str, set] = {}     # object_id -> {task_id}
        self._available: set = set()              # object ids already produced

    def add(self, task: Task):
        self.tasks[task.id] = task
        missing = [d for d in task.deps if d.id not in self._available]
        if not missing:
            task.state = TaskState.READY
            return
        for d in missing:
            self._waiting_on.setdefault(d.id, set()).add(task.id)

    def mark_available(self, object_id: str):
        self._available.add(object_id)

    def object_available(self, ref: ObjectRef) -> List[Task]:
        """Mark an object produced; return tasks that became READY."""
        self._available.add(ref.id)
        ready = []
        for tid in self._waiting_on.pop(ref.id, set()):
            task = self.tasks[tid]
            if task.state != TaskState.PENDING:
                continue
            if all(d.id in self._available for d in task.deps):
                task.state = TaskState.READY
                ready.append(task)
        return ready

    def object_lost(self, object_id: str):
        self._available.discard(object_id)

    def rewait(self, task: Task):
        """Re-register a requeued task for its not-yet-available deps, so
        the (reconstructed) producer's object_available wakes it again --
        graph.add only registered the *first* attempt."""
        for d in task.deps:
            if d.id not in self._available:
                self._waiting_on.setdefault(d.id, set()).add(task.id)

    def ready_tasks(self) -> List[Task]:
        return [t for t in self.tasks.values() if t.state == TaskState.READY]

    def running_tasks(self) -> List[Task]:
        return [t for t in self.tasks.values() if t.state == TaskState.RUNNING]
