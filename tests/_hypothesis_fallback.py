"""Deterministic stand-in for `hypothesis` when it is not installed.

The real library is declared in requirements-dev.txt and is preferred; this
shim only provides the surface the suite actually uses (`@settings`,
`@given`, `st.integers`, `st.lists`, `st.sampled_from`) so collection never
hard-errors on a bare container. Examples are drawn from an RNG seeded per
test function, so runs are reproducible. There is no shrinking and no
example database -- a failing example is reported as a plain assertion.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

HAVE_HYPOTHESIS = False


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", 20)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                example = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *example, **kwargs)
                except Exception as e:  # noqa: BLE001 -- re-raise with context
                    raise AssertionError(
                        f"falsifying example #{i}: "
                        f"{fn.__name__}{example!r}") from e
        # hide the strategy-bound (rightmost) params from pytest, which
        # would otherwise look for fixtures with those names
        params = list(inspect.signature(fn).parameters.values())
        kept = params[:len(params) - len(strats)]
        wrapper.__signature__ = inspect.Signature(kept)
        del wrapper.__wrapped__
        return wrapper
    return deco
