"""Substrate tests: checkpointing (incl. fault-tolerant restart), data
pipeline determinism, optimizers, serving engine, RL envs + rollouts."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.shapes import ShapeConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim.optimizers import (adafactor, adamw, clip_by_global_norm,
                                    make_optimizer, warmup_cosine)
from repro.train.trainer import Preempted, Trainer, TrainerConfig


# ---------------------------------------------------------------- checkpointer

def _toy_state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "opt": {"m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))},
                    "step": jnp.zeros((), jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip_exact(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _toy_state()
    ck.save(7, state, blocking=True)
    like = jax.eval_shape(lambda: state)
    out = ck.restore(like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_n=2)
    for s in (10, 20, 30, 40):
        ck.save(s, _toy_state())
    ck.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2 and kept[-1].endswith(f"{40:010d}")
    assert ck.latest_step() == 40


def test_checkpoint_atomic_no_partial(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _toy_state(), blocking=True)
    # a stale tmp dir from a crashed writer must not be visible as a ckpt
    os.makedirs(tmp_path / ".tmp-99", exist_ok=True)
    assert ck.latest_step() == 5


# ---------------------------------------------------------------- trainer fault tolerance

def _mk_trainer(tmp_path, num_steps=12, fail_at=None):
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg)
    opt = make_optimizer("adamw")
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4, seed=3))
    tcfg = TrainerConfig(num_steps=num_steps, ckpt_every=4, log_every=4,
                         n_microbatches=2)
    crash = {"armed": fail_at is not None}

    def failure_hook(step):
        if crash["armed"] and step == fail_at:
            crash["armed"] = False
            raise KeyboardInterrupt("injected node failure")

    return Trainer(model, opt, pipe, Checkpointer(str(tmp_path)), tcfg,
                   failure_hook=failure_hook)


def test_trainer_crash_restart_bit_exact(tmp_path):
    """Kill training mid-run; a fresh Trainer must resume from the last
    checkpoint and end bit-identical to an uninterrupted run."""
    t_ref = _mk_trainer(tmp_path / "ref")
    final_ref = t_ref.run(t_ref.init_or_restore(seed=0))

    t1 = _mk_trainer(tmp_path / "ft", fail_at=9)
    with pytest.raises(KeyboardInterrupt):
        t1.run(t1.init_or_restore(seed=0))
    # restart: picks up the step-8 checkpoint, replays deterministically
    t2 = _mk_trainer(tmp_path / "ft")
    final_ft = t2.run()
    assert int(t2.ckpt.latest_step()) == 12
    for a, b in zip(jax.tree.leaves(final_ref), jax.tree.leaves(final_ft)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_preemption_checkpoints(tmp_path):
    t = _mk_trainer(tmp_path, num_steps=50)
    state = t.init_or_restore(seed=0)
    t.request_preemption()
    with pytest.raises(Preempted):
        t.run(state)
    assert t.ckpt.latest_step() is not None


def test_trainer_loss_decreases(tmp_path):
    t = _mk_trainer(tmp_path, num_steps=30)
    t.run(t.init_or_restore(seed=0))
    losses = [h["loss"] for h in t.history]
    assert losses[-1] < losses[0], losses


# ---------------------------------------------------------------- data pipeline

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=5)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = p1.iterate(start_step=17)
    b3 = next(it)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_shards_disjoint():
    a = TokenPipeline(DataConfig(1000, 32, 8, shard_id=0, num_shards=2, seed=1))
    b = TokenPipeline(DataConfig(1000, 32, 8, shard_id=1, num_shards=2, seed=1))
    ba, bb = a.batch_at(0), b.batch_at(0)
    assert ba["tokens"].shape == (4, 32)
    assert not np.array_equal(ba["tokens"], bb["tokens"])


def test_pipeline_targets_shifted():
    p = TokenPipeline(DataConfig(1000, 32, 4, seed=2))
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


# ---------------------------------------------------------------- optimizers

def test_adamw_first_step_is_signed_lr():
    opt = adamw(weight_decay=0.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.5, -0.1, 0.0])}
    st = opt.init(params)
    new, st, _ = opt.update(params, grads, st, lr=0.1)
    # bias-corrected first adam step == lr * sign(g) (for g != 0)
    delta = np.asarray(new["w"] - params["w"])
    np.testing.assert_allclose(delta[:2], [-0.1, 0.1], atol=1e-5)
    assert delta[2] == 0.0


def test_adamw_and_adafactor_descend():
    def loss_fn(p):
        return jnp.sum((p["w"] - 3.0) ** 2)
    for opt in (adamw(weight_decay=0.0), adafactor()):
        params = {"w": jnp.zeros((8, 8))}
        st = opt.init(params)
        for _ in range(60):
            g = jax.grad(loss_fn)(params)
            params, st, _ = opt.update(params, g, st, lr=0.3)
        assert float(loss_fn(params)) < 1.0, opt.name


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = opt.init(params)
    assert st["s"]["w"]["vr"].shape == (64,)
    assert st["s"]["w"]["vc"].shape == (32,)
    assert st["s"]["b"]["v"].shape == (64,)


def test_clip_and_schedule():
    tree = {"a": jnp.full((4,), 3.0)}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) <= 1.0 + 1e-5
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(lr(jnp.asarray(100))) < 0.2


# ---------------------------------------------------------------- serving engine

def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=2, max_len=32)
    reqs = [Request(id=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(5)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)
    assert eng.stats["completed"] == 5
    assert eng.stats["prefills"] == 5
    # slots were reused: never more than 2 in flight
    assert eng.stats["ticks"] >= 2 * (5 // 2)


def test_serve_engine_matches_direct_decode():
    """Engine output for a single request == straight prefill+decode."""
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("granite-8b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    prompt = [4, 7, 9]
    eng = ServeEngine(model, params, batch_slots=1, max_len=16)
    req = Request(id=0, prompt=prompt, max_new_tokens=3)
    eng.add_request(req)
    eng.run_until_drained()

    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    toks = [int(jnp.argmax(logits[0, -1]))]
    # direct decode needs a max_len cache; replay through engine-sized cache
    assert req.output[0] == toks[0]
    assert len(req.output) == 3


# ---------------------------------------------------------------- RL substrate

def test_classic_control_dynamics():
    from repro.rl.envs import cartpole_step, pendulum_step
    s = jnp.array([0.0, 0.0, 0.05, 0.0])
    s2, obs, r, done = cartpole_step(s, jnp.asarray(1))
    assert not bool(done) and float(r) == 1.0
    assert abs(float(s2[1])) > 0.0      # force accelerates the cart
    st = jnp.array([0.1, 0.0])
    _, obs, r, _ = pendulum_step(st, jnp.array([0.5]))
    assert obs.shape == (3,) and float(r) <= 0.0


def test_rollout_task_artifact_sizes():
    from repro.rl.envs import ENV_SPECS
    from repro.rl.rollout import rollout_task
    r = rollout_task("Pendulum", 50, seed=0)
    assert r["interactions"] == 50
    assert r["obs"].shape == (50, ENV_SPECS["Pendulum"].obs_dim)
    h = rollout_task("Humanoid", 10, seed=0)
    assert h["obs"].shape == (10, 376)   # the fat artifact (paper's collapse)


def test_rollouts_on_cluster():
    from repro.core import SyndeoCluster
    from repro.rl.rollout import run_benchmark_local
    with SyndeoCluster() as c:
        for _ in range(2):
            c.add_worker()
        tput, stats = run_benchmark_local(c, "Cartpole", n_workers=2,
                                          steps_per_worker=100)
        assert tput > 0 and stats["n_tasks"] == 2
