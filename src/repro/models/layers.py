"""Shared model building blocks, pure JAX.

Attention here is the *reference* (pure-jnp) path: a blocked online-softmax
("flash") implementation whose lowered memory is linear in sequence length,
so the 512-device dry-run's memory_analysis reflects a production-quality
attention. On real TPUs the Pallas kernels in repro.kernels replace the
inner block computation (see kernels/ops.py: use_pallas flag).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.axes import constrain

F32 = jnp.float32
NEG_INF = -1e30

# training attention uses the blockwise custom-VJP backward by default
# (set False to reproduce the paper-faithful §Perf baseline numbers)
FLASH_VJP = True
# int8-KV dequantization dtype for decode attention (bf16 halves the
# dequantized-intermediate HBM traffic; scores still accumulate in fp32)
DEQUANT_DTYPE = jnp.float32
# decode attention kv block size (bigger blocks = fewer loop-boundary
# buffers per step)
DECODE_BLOCK_K = 1024


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, Dh), positions: (..., T) broadcastable."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None, None] * freqs  # (..., T, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jnp.einsum("btd,df->btf", x, w1)
    g = jnp.einsum("btd,df->btf", x, w3)
    h = jax.nn.silu(h.astype(F32)).astype(h.dtype) * g
    return jnp.einsum("btf,fd->btd", h, w2)


# ----------------------------------------------------------------------------
# Blocked flash attention (reference path; memory O(T * block))
# ----------------------------------------------------------------------------

def flash_attention_ref(
    q: jax.Array,                 # (B, Tq, Hq, Dh)
    k: jax.Array,                 # (B, Tk, Hkv, Dh)
    v: jax.Array,                 # (B, Tk, Hkv, Dh)
    *,
    causal: bool = True,
    q_offset: int = 0,            # absolute position of q[0] within the kv axis
    window: Optional[int] = None, # sliding-window size (None = full)
    block_q: int = 512,
    block_k: int = 512,
    valid_len: Optional[jax.Array] = None,  # (B,) traced per-seq kv validity bound
    kv_scale: Optional[jax.Array] = None,   # (B, Tk, Hkv, 1) int8 k dequant scale
    v_scale: Optional[jax.Array] = None,    # (B, Tk, Hkv, 1) int8 v dequant scale
) -> jax.Array:
    """Blocked online-softmax attention with GQA folding.

    The outer loop over q-blocks is a static python loop so that each q-block
    scans only the kv-blocks its causal/window footprint needs -- the lowered
    FLOPs match a production flash kernel (no masked-out waste beyond block
    granularity).
    """
    B, Tq, Hq, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    R = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)

    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    nq = -(-Tq // block_q)
    nk = -(-Tk // block_k)
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, Tk, block_q, block_k)

    if FLASH_VJP and valid_len is None and kv_scale is None and v_scale is None:
        # training path: blockwise custom-VJP (flash backward) -- saves only
        # (q,k,v,o,lse), recomputes p per tile (EXPERIMENTS.md §Perf it1)
        from repro.models.flash_vjp import flash_attention_vjp
        return flash_attention_vjp(q, k, v, causal, window, q_offset,
                                   block_q, block_k)

    qr = q.reshape(B, nq, block_q, Hkv, R, Dh)
    kr = k.reshape(B, nk, block_k, Hkv, Dh)
    vr = v.reshape(B, nk, block_k, Hkv, Dh)
    ksr = kv_scale.reshape(B, nk, block_k, Hkv, 1) if kv_scale is not None else None
    vsr = v_scale.reshape(B, nk, block_k, Hkv, 1) if v_scale is not None else None

    out_blocks = []
    for i in range(nq):
        q_blk = qr[:, i]
        q_start = q_offset + i * block_q
        q_end = q_start + block_q - 1
        # kv-block footprint for this q block (static bounds)
        hi = nk if not causal else min(nk, (q_end // block_k) + 1)
        lo = 0
        if window is not None:
            lo = max(0, (q_start - window + 1) // block_k)
        n_steps = hi - lo
        if n_steps <= 0:
            out_blocks.append(jnp.zeros((B, block_q, Hkv, R, Dh), q.dtype))
            continue

        def body(carry, j):
            acc, m, l = carry
            kb = jax.lax.dynamic_index_in_dim(kr, j, axis=1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, j, axis=1, keepdims=False)
            if ksr is not None:
                sb = jax.lax.dynamic_index_in_dim(ksr, j, axis=1, keepdims=False)
                kb = (kb.astype(F32) * sb).astype(DEQUANT_DTYPE)
            if vsr is not None:
                sb = jax.lax.dynamic_index_in_dim(vsr, j, axis=1, keepdims=False)
                vb = (vb.astype(F32) * sb).astype(DEQUANT_DTYPE)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", q_blk.astype(kb.dtype),
                           kb, preferred_element_type=F32) * scale
            qpos = q_start + jnp.arange(block_q)
            kpos = j * block_k + jnp.arange(block_k)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            if valid_len is not None:
                maskb = mask[None] & (kpos[None, None, :] < valid_len[:, None, None])
            else:
                maskb = mask[None]
            s = jnp.where(maskb[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            # fully-masked rows keep p == 0 (avoid exp(-inf - -inf) == 1)
            p = jnp.exp(s - m_new[..., None]) * maskb[:, None, None]
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhrqk,bkhd->bhrqd", p, vb.astype(F32))
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Hkv, R, block_q, Dh), F32)
        m0 = jnp.full((B, Hkv, R, block_q), NEG_INF, F32)
        l0 = jnp.zeros((B, Hkv, R, block_q), F32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), lo + jnp.arange(n_steps))
        o = acc / jnp.maximum(l, 1e-20)[..., None]
        out_blocks.append(o.transpose(0, 3, 1, 2, 4).astype(q.dtype))  # (B,bq,Hkv,R,Dh)

    out = jnp.concatenate(out_blocks, axis=1) if len(out_blocks) > 1 else out_blocks[0]
    return out.reshape(B, Tq, Hq, Dh)


# ----------------------------------------------------------------------------
# Attention layer (GQA, rope, optional bias) with KV-cache support
# ----------------------------------------------------------------------------

def _q_head_permutation(n_heads, n_kv_heads, hq_pad, hkv_pad):
    """Padded q-head index of each real q head, preserving the GQA q->kv
    group mapping: real head i (group g=i//R, slot s=i%R) lands at
    g*R_pad + s, so under the padded ratio R_pad it still reads kv group g."""
    r_real = n_heads // n_kv_heads
    r_pad = hq_pad // hkv_pad
    return [(i // r_real) * r_pad + (i % r_real) for i in range(n_heads)]


def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, qkv_bias, dtype,
                   pad_q_to: int = 0, pad_kv_to: int = 0):
    """Padded heads (pad_*_to > n_heads) get ZERO weights placed *within*
    their GQA group: a zero-weight q head yields zero output through zero wo
    rows, and real heads keep their kv group, so padding is numerically
    exact (DESIGN.md: TP-compat head padding, like vocab padding)."""
    ks = jax.random.split(key, 4)
    hq, hkv = pad_q_to or n_heads, pad_kv_to or n_kv_heads
    q_dim, kv_dim = hq * head_dim, hkv * head_dim
    std = d_model ** -0.5

    def expand_cols(w_real, perm, tot_heads):
        w = jnp.zeros((w_real.shape[0], tot_heads * head_dim), w_real.dtype)
        for i, j in enumerate(perm):
            w = w.at[:, j * head_dim:(j + 1) * head_dim].set(
                w_real[:, i * head_dim:(i + 1) * head_dim])
        return w

    wq_real = jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * std
    wk_real = jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim)) * std
    wv_real = jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim)) * std
    wo_real = jax.random.normal(ks[3], (n_heads * head_dim, d_model)) * std

    if hq > n_heads or hkv > n_kv_heads:
        qperm = _q_head_permutation(n_heads, n_kv_heads, hq, hkv)
        kvperm = list(range(n_kv_heads))
        wq = expand_cols(wq_real, qperm, hq)
        wk = expand_cols(wk_real, kvperm, hkv)
        wv = expand_cols(wv_real, kvperm, hkv)
        wo = expand_cols(wo_real.T, qperm, hq).T
    else:
        wq, wk, wv, wo = wq_real, wk_real, wv_real, wo_real

    p = {"wq": wq.astype(dtype), "wk": wk.astype(dtype),
         "wv": wv.astype(dtype), "wo": wo.astype(dtype)}
    if qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), dtype)
        p["bk"] = jnp.zeros((kv_dim,), dtype)
        p["bv"] = jnp.zeros((kv_dim,), dtype)
    return p


def attention(
    p, x, positions, cfg, *,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,   # cached (k, v)
    kv_scale: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    block_q: int = 512,
    block_k: int = 512,
):
    """Returns (out, (k, v) of *this* call's tokens for cache append)."""
    B, T, D = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dq->btq", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, T, cfg.eff_q_heads, hd)

    if cross_kv is not None:
        k, v = cross_kv
        new_kv = None
        q = constrain(q, "batch", None, "model", None)
        out = flash_attention_ref(q, k, v, causal=False,
                                  block_q=block_q, block_k=block_k)
    else:
        k = jnp.einsum("btd,dk->btk", x, p["wk"])
        v = jnp.einsum("btd,dk->btk", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(B, T, cfg.eff_kv_heads, hd)
        v = v.reshape(B, T, cfg.eff_kv_heads, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        new_kv = (k, v)
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)
        if kv is not None:
            # decode: attend over the cache (the new token was already
            # scattered into the cache by the caller)
            k, v = kv
            out = flash_attention_ref(q, k, v, causal=False, window=window,
                                      q_offset=q_offset, block_q=block_q,
                                      block_k=block_k, kv_scale=kv_scale)
        else:
            out = flash_attention_ref(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, block_q=block_q,
                                      block_k=block_k)

    out = out.reshape(B, T, cfg.eff_q_heads * hd)
    out = jnp.einsum("btq,qd->btd", out, p["wo"])
    return constrain(out, "batch", None, None), new_kv


# ----------------------------------------------------------------------------
# Embedding / loss
# ----------------------------------------------------------------------------

def init_embedding(key, vocab, d_model, dtype, tie, padded_vocab=None):
    k1, k2 = jax.random.split(key)
    pv = padded_vocab or vocab
    p = {"tok": (jax.random.normal(k1, (pv, d_model)) * 0.02).astype(dtype)}
    if not tie:
        p["out"] = (jax.random.normal(k2, (pv, d_model)) * 0.02).astype(dtype)
    return p


def embed(p, tokens):
    return constrain(jnp.take(p["tok"], tokens, axis=0), "batch", None, None)


def unembed(p, x, n_valid: Optional[int] = None):
    w = p.get("out", p["tok"])
    logits = jnp.einsum("btd,vd->btv", x, w)
    if n_valid is not None and n_valid < w.shape[0]:
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                             logits.ndim - 1)
        logits = jnp.where(vocab_ids < n_valid, logits, -1e9)
    return logits


def softmax_xent(logits: jax.Array, targets: jax.Array, mask=None) -> jax.Array:
    """Numerically-stable token-mean cross entropy; vocab may be sharded."""
    logits = logits.astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
