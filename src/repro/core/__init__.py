"""Syndeo core: the paper's contribution as a composable runtime.

Scheduler-inside-a-scheduler: a dynamic, dependency-driven head-worker
cluster (this package) hosted inside a static gang allocation (Slurm / K8s /
Cloud-TPU queued resources), with a secure containerized bring-up protocol.
The control plane (directory, scheduling, quotas, tickets) lives on the
head; the data plane (blobs) moves peer to peer between worker stores.
"""
from repro.core.autoscaler import Autoscaler, AutoscalerConfig, ScalingEvent
from repro.core.cluster import ContainerSpec, SyndeoCluster
from repro.core.object_store import (GlobalObjectStore, InProcessTransport,
                                     NodeStore, ObjectRef,
                                     QuotaExceededError, RemoteNodeStore,
                                     TCPTransport, TenantQuota, Transport)
from repro.core.scheduler import (DrainState, RateLimitExceeded, Scheduler,
                                  SchedulerConfig, TenantState, TokenBucket,
                                  WorkerIndex, WorkerInfo)
from repro.core.security import (Capability, HybridClock, NonceCache,
                                 SecurityError, Tenant, TransferTicket,
                                 UnprivilegedProfile, set_clock, wall_now)
from repro.core.simulator import (SimCluster, SimCostModel,
                                  lognormal_provision_latency)
from repro.core.task_graph import Task, TaskSpec, TaskState

__all__ = [
    "Autoscaler", "AutoscalerConfig", "ScalingEvent",
    "ContainerSpec", "SyndeoCluster", "DrainState", "GlobalObjectStore",
    "InProcessTransport", "NodeStore",
    "ObjectRef", "QuotaExceededError", "RateLimitExceeded",
    "RemoteNodeStore", "TCPTransport", "TenantQuota", "Transport",
    "Scheduler", "SchedulerConfig", "TenantState", "TokenBucket",
    "TransferTicket", "WorkerIndex",
    "WorkerInfo",
    "Capability", "HybridClock", "NonceCache", "SecurityError", "Tenant",
    "UnprivilegedProfile", "set_clock", "wall_now", "SimCluster",
    "SimCostModel", "Task", "TaskSpec", "TaskState",
    "lognormal_provision_latency",
]
