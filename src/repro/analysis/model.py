"""syndeo-lint: shared AST code model.

Parses a set of Python files into a light-weight model -- classes,
functions, per-function call sites, lock regions and blocking leaves --
that the three analysis passes (``locks``, ``taint``, ``wire``) share.

The model is deliberately conservative and name-based:

* Receiver types come from parameter annotations, ``self``/``cls``,
  local aliases (``c = self.cluster``), and attribute assignments in
  methods (``self.store = GlobalObjectStore()``).  No real inference.
* A method call on an *unknown* receiver fans out to every class method
  with that name, except for a skip-list of names too common to be
  meaningful (``get``, ``close``, ``pop`` ...).  Over-approximating the
  call graph is the right failure mode for a linter that hunts "can
  this path block while a lock is held".
* Calls inside ``lambda`` bodies and nested ``def``s are attributed to
  the nested function (which runs later), never to the enclosing
  statement.  Callbacks stored in attributes (``launch_fn``,
  ``migrate_fn``) are therefore invisible edges -- see
  tests/README.md for the documented blind spots.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

# Method names whose call is treated as a blocking leaf no matter the
# receiver: raw socket ops, transport RPCs, sleeps and waits.
BLOCKING_ATTRS = {
    "accept", "connect", "create_connection", "fetch", "push",
    "readline", "recv", "recvfrom", "select", "sendall", "sleep",
    "wait",
}

# Receiver names whose every method call blocks (process spawning).
BLOCKING_RECEIVERS = {"subprocess"}

# Too-common method names: never fan out on an unknown receiver.
AMBIGUOUS_METHODS = {
    "acquire", "add", "append", "clear", "close", "copy", "count",
    "debug", "decode", "discard", "encode", "error", "exists",
    "extend", "flush", "format", "get", "info", "insert", "items",
    "join", "keys", "kill", "mkdir", "open", "pop", "popitem", "put",
    "read", "register", "release", "remove", "run", "seek", "send",
    "serve_forever", "set", "setdefault", "shutdown", "sort", "split",
    "start", "stop", "strip", "submit", "tell", "terminate", "unlink",
    "update", "values", "warning", "write",
}


@dataclass
class Finding:
    rule: str
    file: str
    line: int
    function: str
    message: str

    def render(self) -> str:
        return (f"{self.file}:{self.line}: {self.rule} "
                f"[{self.function}] {self.message}")


@dataclass
class CallSite:
    line: int
    name: str                     # called attribute / function name
    kind: str                     # "bare" | "method"
    recv_type: Optional[str]      # inferred receiver class, if any
    display: str                  # source-ish text for messages
    under_locks: Tuple[str, ...]  # lock ids held at the call site
    blocking: Optional[str]       # leaf description if directly blocking


@dataclass
class LockAcq:
    lock_id: str
    line: int
    held: Tuple[str, ...]         # locks already held when acquired


@dataclass
class FunctionInfo:
    file: str
    qualname: str
    name: str
    class_name: Optional[str]
    node: ast.AST
    calls: List[CallSite] = field(default_factory=list)
    lock_acqs: List[LockAcq] = field(default_factory=list)

    @property
    def key(self) -> str:
        return f"{self.file}::{self.qualname}"


@dataclass
class ClassInfo:
    name: str
    file: str
    bases: List[str]
    methods: Dict[str, FunctionInfo] = field(default_factory=dict)
    attr_types: Dict[str, str] = field(default_factory=dict)


def _src(node: ast.AST, limit: int = 48) -> str:
    try:
        text = ast.unparse(node)
    except Exception:  # pragma: no cover -- unparse is total on 3.9+
        text = "<expr>"
    return text if len(text) <= limit else text[:limit - 3] + "..."


def _annotation_type(ann: Optional[ast.AST]) -> Optional[str]:
    """Best-effort class name out of an annotation node."""
    if ann is None:
        return None
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split("[")[0].split(".")[-1].strip("'\" ") or None
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Subscript):
        base = _annotation_type(ann.value)
        if base == "Optional":
            return _annotation_type(ann.slice)
    return None


class CodeModel:
    """Classes + functions + a conservative name-based call graph."""

    def __init__(self) -> None:
        self.functions: Dict[str, FunctionInfo] = {}
        self.classes: Dict[str, List[ClassInfo]] = {}
        self.module_functions: Dict[str, List[FunctionInfo]] = {}
        self._subclasses: Dict[str, Set[str]] = {}
        self._blocking: Optional[
            Dict[str, Tuple[str, int, Optional[str]]]] = None
        self._acquired: Optional[
            Dict[str, Dict[str, Tuple[str, int]]]] = None

    # -- construction -----------------------------------------------------

    def index_subclasses(self) -> None:
        direct: Dict[str, Set[str]] = {}
        for cls_list in self.classes.values():
            for ci in cls_list:
                for b in ci.bases:
                    direct.setdefault(b, set()).add(ci.name)
        # transitive closure
        def close(name: str, seen: Set[str]) -> Set[str]:
            out: Set[str] = set()
            for sub in direct.get(name, ()):
                if sub in seen:
                    continue
                seen.add(sub)
                out.add(sub)
                out |= close(sub, seen)
            return out

        for name in self.classes:
            self._subclasses[name] = close(name, {name})

    # -- typing helpers ---------------------------------------------------

    def type_of(self, e: ast.AST, env: Dict[str, str]) -> Optional[str]:
        if isinstance(e, ast.Name):
            return env.get(e.id)
        if isinstance(e, ast.Attribute):
            base = self.type_of(e.value, env)
            if base:
                for ci in self.classes.get(base, []):
                    t = ci.attr_types.get(e.attr)
                    if t:
                        return t
            return None
        if isinstance(e, ast.Call):
            fname = None
            if isinstance(e.func, ast.Name):
                fname = e.func.id
            elif isinstance(e.func, ast.Attribute):
                fname = e.func.attr
            if fname in self.classes:
                return fname
            return None
        if isinstance(e, ast.BoolOp):
            for v in reversed(e.values):
                t = self.type_of(v, env)
                if t:
                    return t
            return None
        if isinstance(e, ast.IfExp):
            return (self.type_of(e.body, env)
                    or self.type_of(e.orelse, env))
        if isinstance(e, ast.Await):
            return self.type_of(e.value, env)
        return None

    # -- call resolution --------------------------------------------------

    def _lookup_method(self, cname: str, mname: str,
                       seen: Set[str]) -> Optional[FunctionInfo]:
        if cname in seen:
            return None
        seen.add(cname)
        for ci in self.classes.get(cname, []):
            if mname in ci.methods:
                return ci.methods[mname]
            for b in ci.bases:
                hit = self._lookup_method(b, mname, seen)
                if hit:
                    return hit
        return None

    def methods_of(self, cname: str, mname: str) -> List[FunctionInfo]:
        """Method `mname` on class `cname`, its base chain, and any
        subclass override (subclasses matter because attributes are often
        typed as the base while holding a remote/blocking variant)."""
        out: List[FunctionInfo] = []
        seen_keys: Set[str] = set()
        names = [cname] + sorted(self._subclasses.get(cname, ()))
        for nm in names:
            hit = self._lookup_method(nm, mname, set())
            if hit and hit.key not in seen_keys:
                seen_keys.add(hit.key)
                out.append(hit)
        return out

    def resolve_call(self, fn: FunctionInfo,
                     cs: CallSite) -> List[FunctionInfo]:
        if cs.kind == "bare":
            out: List[FunctionInfo] = []
            nested = self.functions.get(
                f"{fn.file}::{fn.qualname}.{cs.name}")
            if nested:
                out.append(nested)
            out.extend(self.module_functions.get(cs.name, []))
            return out
        if cs.recv_type:
            targets = self.methods_of(cs.recv_type, cs.name)
            if targets:
                return targets
        if cs.name in AMBIGUOUS_METHODS:
            return []
        out, seen = [], set()
        for cls_list in self.classes.values():
            for ci in cls_list:
                m = ci.methods.get(cs.name)
                if m and m.key not in seen:
                    seen.add(m.key)
                    out.append(m)
        return out

    # -- fixpoints --------------------------------------------------------

    def blocking_info(self) -> Dict[str, Tuple[str, int, Optional[str]]]:
        """fn key -> (display, line, next key or None for a leaf)."""
        if self._blocking is not None:
            return self._blocking
        info: Dict[str, Tuple[str, int, Optional[str]]] = {}
        for key, fn in self.functions.items():
            for cs in fn.calls:
                if cs.blocking:
                    info[key] = (cs.display, cs.line, None)
                    break
        changed = True
        while changed:
            changed = False
            for key, fn in self.functions.items():
                if key in info:
                    continue
                hit = None
                for cs in fn.calls:
                    for tgt in self.resolve_call(fn, cs):
                        if tgt.key in info and tgt.key != key:
                            hit = (cs.display, cs.line, tgt.key)
                            break
                    if hit:
                        break
                if hit:
                    info[key] = hit
                    changed = True
        self._blocking = info
        return info

    def blocking_chain(self, key: str, limit: int = 6) -> str:
        info = self.blocking_info()
        parts: List[str] = []
        cur: Optional[str] = key
        for _ in range(limit):
            if cur is None or cur not in info:
                break
            display, _line, nxt = info[cur]
            parts.append(f"{display}()")
            cur = nxt
        return " -> ".join(parts)

    def acquired_info(self) -> Dict[str, Dict[str, Tuple[str, int]]]:
        """fn key -> {lock id acquired during execution: witness}."""
        if self._acquired is not None:
            return self._acquired
        acq: Dict[str, Dict[str, Tuple[str, int]]] = {}
        for key, fn in self.functions.items():
            acq[key] = {a.lock_id: (fn.file, a.line) for a in fn.lock_acqs}
        changed = True
        while changed:
            changed = False
            for key, fn in self.functions.items():
                mine = acq[key]
                for cs in fn.calls:
                    for tgt in self.resolve_call(fn, cs):
                        for lid, wit in acq.get(tgt.key, {}).items():
                            if lid not in mine:
                                mine[lid] = wit
                                changed = True
        self._acquired = acq
        return acq


# -- builder --------------------------------------------------------------


def _py_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


def _display_path(p: Path) -> str:
    try:
        return os.path.relpath(p)
    except ValueError:  # pragma: no cover -- different drive on win32
        return str(p)


def build_model(paths: Iterable[str]) -> CodeModel:
    model = CodeModel()
    trees: List[Tuple[str, ast.Module]] = []
    for f in _py_files(paths):
        trees.append((_display_path(f),
                      ast.parse(f.read_text(), filename=str(f))))
    for fname, tree in trees:
        _register(model, fname, tree.body, qual=[], cls=None, depth=0)
    model.index_subclasses()
    for _ in range(2):  # two rounds: attribute types that chain
        for cls_list in model.classes.values():
            for ci in cls_list:
                _infer_attr_types(model, ci)
    for fn in list(model.functions.values()):
        _scan_function(model, fn)
    return model


def _register(model: CodeModel, fname: str, stmts: List[ast.stmt],
              qual: List[str], cls: Optional[ClassInfo],
              depth: int) -> None:
    for st in stmts:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            qn = ".".join(qual + [st.name])
            fn = FunctionInfo(file=fname, qualname=qn, name=st.name,
                              class_name=cls.name if cls else None,
                              node=st)
            model.functions[fn.key] = fn
            if cls is not None:
                cls.methods.setdefault(st.name, fn)
            elif depth == 0:
                model.module_functions.setdefault(st.name, []).append(fn)
            _register(model, fname, st.body, qual + [st.name], None,
                      depth + 1)
        elif isinstance(st, ast.ClassDef):
            bases = [b for b in (_annotation_type(x) for x in st.bases)
                     if b]
            ci = ClassInfo(name=st.name, file=fname, bases=bases)
            model.classes.setdefault(st.name, []).append(ci)
            _register(model, fname, st.body, qual + [st.name], ci,
                      depth + 1)
        elif isinstance(st, (ast.If, ast.Try, ast.With)):
            # defs guarded by try/except ImportError etc.
            for body in _sub_bodies(st):
                _register(model, fname, body, qual, cls, depth)


def _sub_bodies(st: ast.stmt) -> Iterator[List[ast.stmt]]:
    if isinstance(st, ast.If):
        yield st.body
        yield st.orelse
    elif isinstance(st, ast.Try):
        yield st.body
        for h in st.handlers:
            yield h.body
        yield st.orelse
        yield st.finalbody
    elif isinstance(st, ast.With):
        yield st.body


def _param_env(fn: FunctionInfo) -> Dict[str, str]:
    env: Dict[str, str] = {}
    node = fn.node
    args = node.args
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        t = _annotation_type(a.annotation)
        if t:
            env[a.arg] = t
    positional = args.posonlyargs + args.args
    if fn.class_name and positional:
        env[positional[0].arg] = fn.class_name
    return env


def _infer_attr_types(model: CodeModel, ci: ClassInfo) -> None:
    for method in ci.methods.values():
        env = _param_env(method)
        for st in _own_statements(method.node):
            if isinstance(st, ast.Assign):
                for tgt in st.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        t = model.type_of(st.value, env)
                        if t:
                            ci.attr_types[tgt.attr] = t
            elif isinstance(st, ast.AnnAssign):
                tgt = st.target
                if (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    t = (_annotation_type(st.annotation)
                         or (st.value is not None
                             and model.type_of(st.value, env) or None))
                    if t:
                        ci.attr_types[tgt.attr] = t


def _own_statements(node: ast.AST) -> Iterator[ast.stmt]:
    """All statements of a function body, not descending into nested
    function/class definitions."""
    stack: List[ast.stmt] = list(getattr(node, "body", []))
    while stack:
        st = stack.pop()
        yield st
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            continue
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.stmt):
                stack.append(child)
            elif isinstance(child, (ast.ExceptHandler,)):
                stack.extend(child.body)


def calls_in(e: ast.AST) -> Iterator[ast.Call]:
    """Every Call in an expression, not descending into lambdas."""
    stack: List[ast.AST] = [e]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Lambda):
            continue
        if isinstance(n, ast.Call):
            yield n
        stack.extend(ast.iter_child_nodes(n))


def _scan_function(model: CodeModel, fn: FunctionInfo) -> None:
    env = _param_env(fn)
    _scan_block(model, fn, list(getattr(fn.node, "body", [])), env, [])


def _scan_block(model: CodeModel, fn: FunctionInfo,
                stmts: List[ast.stmt], env: Dict[str, str],
                locks: List[str]) -> None:
    for st in stmts:
        _scan_stmt(model, fn, st, env, locks)


def _lock_id(model: CodeModel, e: ast.AST,
             env: Dict[str, str]) -> Optional[str]:
    if isinstance(e, ast.Attribute) and e.attr in ("_lock", "lock"):
        t = model.type_of(e.value, env)
        return f"{t or '?'}.{e.attr}"
    if isinstance(e, ast.Name) and e.id.endswith("_lock"):
        return f"<local>.{e.id}"
    return None


def _scan_stmt(model: CodeModel, fn: FunctionInfo, st: ast.stmt,
               env: Dict[str, str], locks: List[str]) -> None:
    if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                       ast.ClassDef)):
        return  # separate FunctionInfo; runs later, not under these locks
    if isinstance(st, ast.Assign):
        _scan_expr(model, fn, st.value, env, locks)
        t = model.type_of(st.value, env)
        for tgt in st.targets:
            if isinstance(tgt, ast.Name):
                if t:
                    env[tgt.id] = t
                else:
                    env.pop(tgt.id, None)
            else:
                _scan_expr(model, fn, tgt, env, locks)
        return
    if isinstance(st, ast.AnnAssign):
        if st.value is not None:
            _scan_expr(model, fn, st.value, env, locks)
        if isinstance(st.target, ast.Name):
            t = _annotation_type(st.annotation)
            if t:
                env[st.target.id] = t
        return
    if isinstance(st, ast.AugAssign):
        _scan_expr(model, fn, st.value, env, locks)
        return
    if isinstance(st, (ast.With, ast.AsyncWith)):
        inner = list(locks)
        for item in st.items:
            lid = _lock_id(model, item.context_expr, env)
            if lid:
                fn.lock_acqs.append(
                    LockAcq(lid, item.context_expr.lineno, tuple(inner)))
                inner.append(lid)
            else:
                _scan_expr(model, fn, item.context_expr, env, inner)
        _scan_block(model, fn, st.body, env, inner)
        return
    if isinstance(st, ast.If):
        _scan_expr(model, fn, st.test, env, locks)
        _scan_block(model, fn, st.body, env, locks)
        _scan_block(model, fn, st.orelse, env, locks)
        return
    if isinstance(st, ast.While):
        _scan_expr(model, fn, st.test, env, locks)
        _scan_block(model, fn, st.body, env, locks)
        _scan_block(model, fn, st.orelse, env, locks)
        return
    if isinstance(st, ast.For):
        _scan_expr(model, fn, st.iter, env, locks)
        _scan_block(model, fn, st.body, env, locks)
        _scan_block(model, fn, st.orelse, env, locks)
        return
    if isinstance(st, ast.Try):
        _scan_block(model, fn, st.body, env, locks)
        for h in st.handlers:
            _scan_block(model, fn, h.body, env, locks)
        _scan_block(model, fn, st.orelse, env, locks)
        _scan_block(model, fn, st.finalbody, env, locks)
        return
    for child in ast.iter_child_nodes(st):
        if isinstance(child, ast.expr):
            _scan_expr(model, fn, child, env, locks)


def _scan_expr(model: CodeModel, fn: FunctionInfo, e: ast.AST,
               env: Dict[str, str], locks: List[str]) -> None:
    for call in calls_in(e):
        _record_call(model, fn, call, env, locks)


def _record_call(model: CodeModel, fn: FunctionInfo, call: ast.Call,
                 env: Dict[str, str], locks: List[str]) -> None:
    f = call.func
    if isinstance(f, ast.Name):
        fn.calls.append(CallSite(
            line=call.lineno, name=f.id, kind="bare", recv_type=None,
            display=f.id, under_locks=tuple(locks), blocking=None))
        return
    if isinstance(f, ast.Attribute):
        blocking = None
        display = _src(f)
        if f.attr in BLOCKING_ATTRS:
            blocking = display
        if (isinstance(f.value, ast.Name)
                and f.value.id in BLOCKING_RECEIVERS):
            blocking = display
        fn.calls.append(CallSite(
            line=call.lineno, name=f.attr, kind="method",
            recv_type=model.type_of(f.value, env), display=display,
            under_locks=tuple(locks), blocking=blocking))
