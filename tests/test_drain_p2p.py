"""Direct worker<->worker drain migration: the two-phase move protocol
(PREPARE / direct push / destination-ack COMMIT / probe-first ABORT +
re-plan), the migrate-right tickets that authorize it, and the chaos
conformance scenarios for every fault class on the migration path --
source kill, destination kill, dropped commit, expired ticket, partition.

The protocol scenarios run over REAL sockets (each fake peer is a live
BlobServer + NodeStore joined to a real HeadServer); the harness drives
the control-plane messages one by one so a fault can be injected between
any two of them. After every scenario the global invariant checker
(tests/_invariants.py, documented in tests/README.md) must pass and the
head's control socket must have carried zero payload bytes."""
import pickle
import socket
import threading
import time

import pytest

from _invariants import check_invariants, check_metrics_conformance
from repro.core import (GlobalObjectStore, NodeStore, ObjectRef, Scheduler,
                        SchedulerConfig, SecurityError, SimCluster,
                        SimCostModel, SyndeoCluster, TCPTransport,
                        TenantQuota, TransferTicket, WorkerInfo)
from repro.core.rendezvous import FileRendezvous
from repro.core.security import mint_cluster_token
from repro.core.task_graph import TaskState
from repro.core.worker import (BlobServer, HeadServer, push_with_retry,
                               run_worker)

TOKEN = mint_cluster_token()


# ------------------------------------------------ two-phase move state machine


def _store_with(*nodes):
    g = GlobalObjectStore()
    for n in nodes:
        g.register_node(NodeStore(n, capacity_bytes=1 << 30))
    return g


def test_begin_commit_hands_off_owner():
    g = _store_with("head", "w0", "w1")
    ref = g.put("w0", {"v": 1})
    assert g.begin_move(ref, "w0", "w1")
    # PREPARE changes nothing visible: src still owns and serves
    assert g.move_in_flight(ref) == ("w0", "w1")
    assert g.locations(ref) == {"w0"} and g.owner_of(ref) == "w0"
    check_invariants(g)
    # the push lands (out of band), then the destination ack commits
    g._nodes["w1"].import_blob(ref, g._nodes["w0"].export_blob(ref))
    assert g.commit_move(ref, "w0", "w1")
    assert g.locations(ref) == {"w1"} and g.owner_of(ref) == "w1"
    assert not g._nodes["w0"].has(ref)         # source copy deleted
    assert g.move_in_flight(ref) is None
    assert g.stats["moves_committed"] == 1
    check_invariants(g)


def test_begin_move_refuses_double_prepare_and_stale_args():
    g = _store_with("head", "w0", "w1")
    ref = g.put("w0", b"x")
    assert not g.begin_move(ref, "w1", "w0")         # src holds nothing
    assert not g.begin_move(ref, "w0", "nope")       # unknown destination
    assert g.begin_move(ref, "w0", "w1")
    assert not g.begin_move(ref, "w0", "head")       # already mid-move
    # commit must name the exact prepared (src, dst)
    assert not g.commit_move(ref, "w0", "head")
    assert g.move_in_flight(ref) == ("w0", "w1")


def test_abort_probe_promotes_landed_push_to_commit():
    """Dropped COMMIT: the push landed but the ack was lost -- the abort
    probe finds the blob at the destination and commits instead of
    re-copying (zero wasted bytes, no duplicated ownership)."""
    g = _store_with("head", "w0", "w1")
    ref = g.put("w0", bytearray(1000))
    assert g.begin_move(ref, "w0", "w1")
    g._nodes["w1"].import_blob(ref, g._nodes["w0"].export_blob(ref))
    assert g.abort_move(ref, probe=True) is True     # promoted to COMMIT
    assert g.locations(ref) == {"w1"} and g.owner_of(ref) == "w1"
    assert g.stats["moves_committed"] == 1
    assert g.stats["moves_aborted"] == 0
    check_invariants(g)


def test_abort_without_landed_push_strands_nothing():
    g = _store_with("head", "w0", "w1")
    ref = g.put("w0", b"y" * 100)
    assert g.begin_move(ref, "w0", "w1")
    assert g.abort_move(ref, probe=True) is False
    # the directory never changed: src still owns, and a fresh PREPARE works
    assert g.locations(ref) == {"w0"} and g.owner_of(ref) == "w0"
    assert g.stats["moves_aborted"] == 1
    assert g.begin_move(ref, "w0", "w1")
    check_invariants(g)


def test_release_mid_move_drops_pushed_copy():
    """An object released while its move is in flight must not strand the
    pushed bytes at the destination."""
    g = _store_with("head", "w0", "w1")
    ref = g.put("w0", b"z" * 500)
    assert g.begin_move(ref, "w0", "w1")
    g._nodes["w1"].import_blob(ref, g._nodes["w0"].export_blob(ref))
    g.release(ref)                                   # refcount 1 -> 0
    assert g.move_in_flight(ref) is None
    assert not g._nodes["w1"].has(ref)
    assert not g.commit_move(ref, "w0", "w1")        # late ack: no-op
    check_invariants(g)


def test_node_death_aborts_involving_moves():
    g = _store_with("head", "w0", "w1", "w2")
    a = g.put("w0", b"a")
    b = g.put("w1", b"b")
    assert g.begin_move(a, "w0", "w2")               # w0 is a source
    assert g.begin_move(b, "w1", "w0")               # w0 is a destination
    g.unregister_node("w0")
    assert g.move_in_flight(a) is None
    assert g.move_in_flight(b) is None
    # b is untouched (its source survives); a lost its only copy
    assert g.locations(b) == {"w1"} and g.owner_of(b) == "w1"
    assert g.locations(a) == set()
    check_invariants(g)


def test_commit_move_with_unregistered_destination_returns_false():
    """Regression (review): a COMMIT whose destination store vanished
    must report failure cleanly -- directory untouched, source copy
    kept -- not crash."""
    g = _store_with("head", "w0", "w1")
    ref = g.put("w0", b"q" * 200)
    assert g.begin_move(ref, "w0", "w1")
    # the destination unregisters out from under the move, but the move
    # record is re-created (simulating a commit racing the unregister)
    with g._lock:
        del g._nodes["w1"]
    assert g.commit_move(ref, "w0", "w1") is False
    assert g.locations(ref) == {"w0"} and g.owner_of(ref) == "w0"
    assert g._nodes["w0"].has(ref)


def test_coheld_object_under_two_drains_moves_once():
    """Regression (review): two draining workers co-holding an object
    must not abort each other's in-flight move -- the object lands on
    the survivor without transfer ping-pong."""
    cost = SimCostModel(task_time_s=lambda s: 0.01, jitter=0.0,
                        data_plane="p2p", result_location="worker",
                        migration_bandwidth_Bps=1.0e6)   # slow: wide window
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    w0, w1, w2 = sim.add_workers(3)
    ref = sim.store.put(w0, bytearray(500_000))          # ~0.5s per move
    sim.store.get(w1, ref)                               # co-held: w0 + w1
    assert sim.store.locations(ref) == {w0, w1}
    sim.drain_worker_at(w0, 0.0)
    sim.drain_worker_at(w1, 0.0)
    sim.run()
    assert w0 not in sim.scheduler.workers
    assert w1 not in sim.scheduler.workers
    locs = sim.store.locations(ref)
    assert locs and locs <= {w2, "head"}
    assert sim.store.stats["moves_aborted"] == 0         # no ping-pong
    check_invariants(sim.store, expect_fetchable={ref.id},
                     scheduler=sim.scheduler,
                     expect_zero_reconstructions=True)
    check_metrics_conformance(sim.store, sim.scheduler)


def test_complete_move_is_begin_plus_commit():
    """The in-process path (sim / threaded backends / relay fallback)."""
    g = _store_with("head", "w0", "w1")
    ref = g.put("w0", {"k": [1, 2, 3]})
    assert g.begin_move(ref, "w0", "w1")
    assert g.complete_move(ref, "w0", "w1")
    assert g.locations(ref) == {"w1"} and g.owner_of(ref) == "w1"
    assert g.get("head", ref) == {"k": [1, 2, 3]}
    check_invariants(g)


# ------------------------------------------------- migrate-right ticket wire


def test_migrate_ticket_bindings():
    t = TransferTicket.grant_migrate(TOKEN, "obj1", "dstW", "srcW", "alice",
                                     ttl_s=30.0)
    assert t.right == "migrate"
    t.verify(TOKEN, "obj1", "dstW", "srcW", "migrate",
             object_tenant="alice")
    with pytest.raises(SecurityError):
        t.verify(TOKEN, "obj1", "dstW", "srcW", "put")   # not a put grant
    with pytest.raises(SecurityError):
        t.verify(TOKEN, "obj1", "dstW", "evil", "migrate")  # other pusher
    with pytest.raises(SecurityError):
        t.verify(TOKEN, "obj1", "other", "srcW", "migrate")  # other dest


def test_blob_server_accepts_migrate_push_and_fires_ack(tmp_path):
    """Wire-level: a put under a migrate-right ticket is admitted, adopts
    the ticket's tenant, and fires the destination's on_migrate ack; a
    get-right ticket presented for a push is refused."""
    store = NodeStore("dstW", spill_dir=str(tmp_path))
    acks = []
    srv = BlobServer(store, TOKEN,
                     on_migrate=lambda oid, tenant: acks.append((oid,
                                                                 tenant)))
    try:
        transport = TCPTransport(lambda _n: srv.endpoint, TOKEN, "srcW")
        ref = ObjectRef("objm")
        blob = pickle.dumps({"fat": 1})
        wrong = TransferTicket.grant(TOKEN, "objm", "dstW", "srcW",
                                     "alice", "get", ttl_s=30)
        with pytest.raises(SecurityError):
            transport.push("dstW", ref, blob, wrong)
        assert acks == []
        good = TransferTicket.grant_migrate(TOKEN, "objm", "dstW", "srcW",
                                            "alice", ttl_s=30)
        transport.push("dstW", ref, blob, good)
        assert store.has(ref)
        assert acks == [("objm", "alice")]
        # a plain replication put (right "put") does NOT fire the ack
        put = TransferTicket.grant(TOKEN, "objp", "dstW", "srcW",
                                   "alice", "put", ttl_s=30)
        transport.push("dstW", ObjectRef("objp"), blob, put)
        assert acks == [("objm", "alice")]
    finally:
        srv.shutdown()


# ------------------------------------------ transient-transport retry/fallback


class _FlakyTransport:
    """Transport fake: raises the scripted exceptions, then succeeds."""

    def __init__(self, failures):
        self.failures = list(failures)
        self.calls = 0

    def push(self, node_id, ref, blob, ticket=None):
        self.calls += 1
        if self.failures:
            raise self.failures.pop(0)


def test_push_with_retry_flaky_transport():
    # one transient fault: retried once, succeeds, no error surfaced
    t = _FlakyTransport([ConnectionResetError("reset")])
    err, retryable = push_with_retry(t, "d", ObjectRef("o"), b"b", None)
    assert err is None and not retryable and t.calls == 2
    # persistent transport fault: surfaced as retryable (head falls back
    # to the relay path, never to lineage)
    t = _FlakyTransport([socket.timeout("t"), ConnectionRefusedError("r")])
    err, retryable = push_with_retry(t, "d", ObjectRef("o"), b"b", None)
    assert isinstance(err, OSError) and retryable and t.calls == 2
    # protocol refusal (bad/expired ticket): no retry, not retryable
    t = _FlakyTransport([SecurityError("expired")])
    err, retryable = push_with_retry(t, "d", ObjectRef("o"), b"b", None)
    assert isinstance(err, SecurityError) and not retryable and t.calls == 1


# ---------------------------------------------- quota-aware drain destinations


def test_drain_planner_skips_quota_pinched_survivor():
    """A move must not land where the owning tenant is already memory-rich:
    the survivor breaching TenantQuota.max_bytes_per_node is skipped even
    though it would win on link load / join order."""
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(enable_speculation=False))
    moves = []
    sched.migrate_fn = lambda w, ref, dst: moves.append((ref.id, dst))
    store.register_node(NodeStore("head", capacity_bytes=1 << 30))
    for n in ("v", "s1", "s2"):
        store.register_node(NodeStore(n, capacity_bytes=1 << 30))
        sched.add_worker(WorkerInfo(n, {"cpu": 1.0}))
    store.set_quota("t", TenantQuota(max_bytes_per_node=100_000))
    store.put("s1", b"x" * 90_000, tenant="t")       # memory-rich on s1
    ref = store.put("v", b"y" * 50_000, tenant="t")
    assert sched.begin_drain("v")
    assert moves == [(ref.id, "s2")]
    # without the pinch the planner would have taken s1 (earlier join)
    moves2 = []
    sched2 = Scheduler(store2 := GlobalObjectStore(), lambda t, w: None,
                       config=SchedulerConfig(enable_speculation=False))
    sched2.migrate_fn = lambda w, ref, dst: moves2.append((ref.id, dst))
    store2.register_node(NodeStore("head", capacity_bytes=1 << 30))
    for n in ("v", "s1", "s2"):
        store2.register_node(NodeStore(n, capacity_bytes=1 << 30))
        sched2.add_worker(WorkerInfo(n, {"cpu": 1.0}))
    store2.put("s1", b"x" * 90_000, tenant="t")
    r2 = store2.put("v", b"y" * 50_000, tenant="t")
    assert sched2.begin_drain("v")
    assert moves2 == [(r2.id, "s1")]


def test_quota_pinched_everywhere_still_overflows_to_head():
    """When every survivor breaches the tenant's per-node cap, the head
    fallback still takes the move -- dropping the last copy is worse."""
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(enable_speculation=False))
    moves = []
    sched.migrate_fn = lambda w, ref, dst: moves.append((ref.id, dst))
    store.register_node(NodeStore("head", capacity_bytes=1 << 30))
    for n in ("v", "s1"):
        store.register_node(NodeStore(n, capacity_bytes=1 << 30))
        sched.add_worker(WorkerInfo(n, {"cpu": 1.0}))
    store.set_quota("t", TenantQuota(max_bytes_per_node=10_000))
    store.put("s1", b"x" * 9_000, tenant="t")
    ref = store.put("v", b"y" * 5_000, tenant="t")
    assert sched.begin_drain("v")
    assert moves == [(ref.id, "head")]


# ------------------------------------------------------- replica GC hints


def test_client_read_replicas_released_on_refcount_drop():
    """Regression (ROADMAP "Remaining"): head copies materialized by
    client reads are GCed once the refcount drops -- the head store
    footprint returns to baseline after a read burst."""
    g = _store_with("head", "w0")
    baseline = g._nodes["head"].used_bytes
    refs = [g.put("w0", bytes(10_000)) for _ in range(5)]
    for r in refs:
        g.add_ref(r)                       # a consumer still holds it
        assert g.get("head", r) is not None    # the client read burst
        g.mark_client_read(r)
    assert g._nodes["head"].used_bytes > baseline
    for r in refs:
        g.release(r)                       # refcount 2 -> 1: still alive
    assert g._nodes["head"].used_bytes == baseline
    assert g.stats["replica_gc"] == 5
    for r in refs:
        assert g.locations(r) == {"w0"} and g.owner_of(r) == "w0"
        assert g.refcount(r) == 1
        assert g.get("head", r) is not None    # still fetchable (re-stages)
    check_invariants(g)


def test_owner_copy_on_head_is_never_gced():
    g = _store_with("head", "w0")
    ref = g.put("head", bytes(1000))       # the head IS the owner
    g.add_ref(ref)
    g.mark_client_read(ref)                # hint refused: owner copy
    g.release(ref)
    assert g.locations(ref) == {"head"}
    assert g.stats["replica_gc"] == 0


def test_cluster_get_marks_client_reads():
    def produce():
        return bytes(5000)

    with SyndeoCluster() as cluster:
        cluster.add_worker()
        t = cluster.submit(produce)
        assert cluster.get(t, timeout=30) is not None
        ref = cluster.scheduler.graph.tasks[t.id].output
        assert ref.id in cluster.store._shard(ref.id).client_reads


# ------------------------------------------------- sim: drain plane modeling


def _p2p_sim(migration_timeout_s=10.0, seed=0):
    cost = SimCostModel(task_time_s=lambda s: 0.01, jitter=0.0,
                        data_plane="p2p", result_location="worker")
    return SimCluster(cost, SchedulerConfig(
        enable_speculation=False, heartbeat_timeout=1e9,
        migration_timeout_s=migration_timeout_s), seed=seed)


def test_sim_p2p_drain_moves_zero_head_bytes():
    sim = _p2p_sim()
    victim = sim.add_workers(1)[0]
    sim.add_workers(2)
    refs = [sim.store.put(victim, bytearray(100_000)) for _ in range(4)]
    sim.drain_worker_at(victim, 0.0)
    sim.run()
    assert victim not in sim.scheduler.workers
    assert sim.store.stats["head_relayed_bytes"] == 0
    check_invariants(sim.store, expect_fetchable={r.id for r in refs},
                     scheduler=sim.scheduler,
                     expect_zero_reconstructions=True)
    check_metrics_conformance(sim.store, sim.scheduler)


def test_sim_dropped_commit_recovered_by_probe():
    """Chaos: the copy lands but the COMMIT is dropped. The re-plan scan
    probes the destination, finds the blob, and promotes the move to a
    COMMIT -- no re-copy, no lost object, no duplicate ownership."""
    sim = _p2p_sim(migration_timeout_s=0.5)
    victim = sim.add_workers(1)[0]
    survivors = sim.add_workers(2)
    ref = sim.store.put(victim, bytearray(50_000))
    orig_complete = sim.store.complete_move
    state = {"dropped": False}

    def lossy_complete(r, src, dst):
        if not state["dropped"]:
            state["dropped"] = True
            # the push lands at dst but the COMMIT never happens
            blob = sim.store._nodes[src].export_blob(r)
            sim.store._nodes[dst].import_blob(r, blob)
            return False
        return orig_complete(r, src, dst)

    sim.store.complete_move = lossy_complete
    sim.drain_worker_at(victim, 0.0)
    sim.run()
    assert state["dropped"]
    assert victim not in sim.scheduler.workers
    locs = sim.store.locations(ref)
    assert locs and locs <= set(survivors) | {"head"}
    assert sim.store.owner_of(ref) in locs
    assert sim.store.stats["moves_committed"] == 1   # probe-commit, no redo
    check_invariants(sim.store, expect_fetchable={ref.id},
                     scheduler=sim.scheduler,
                     expect_zero_reconstructions=True)
    check_metrics_conformance(sim.store, sim.scheduler)


def test_sim_destination_death_mid_move_replans():
    """Chaos: the destination dies while the push is in flight -- the
    move aborts and the object re-plans onto a live survivor."""
    cost = SimCostModel(task_time_s=lambda s: 0.01, jitter=0.0,
                        data_plane="p2p", result_location="worker",
                        migration_bandwidth_Bps=1.0e6)    # slow: wide window
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    victim = sim.add_workers(1)[0]
    s1, s2 = sim.add_workers(2)
    ref = sim.store.put(victim, bytearray(1_000_000))     # ~1s transfer
    sim.drain_worker_at(victim, 0.0)
    # the planner picks the first survivor; kill it mid-transfer
    sim.fail_worker_at(s1, 0.3)
    sim.run()
    assert victim not in sim.scheduler.workers
    locs = sim.store.locations(ref)
    assert locs and locs <= {s2, "head"}
    check_invariants(sim.store, expect_fetchable={ref.id},
                     scheduler=sim.scheduler,
                     expect_zero_reconstructions=True)
    check_metrics_conformance(sim.store, sim.scheduler)


# ----------------------------------- TCP protocol conformance (real sockets)


class _Peer:
    """A controllable p2p worker: a REAL NodeStore + BlobServer joined to
    a real HeadServer over the join op. Tests drive the migrate protocol
    message by message (poll, push, ack, failure report) so a fault can
    be injected between any two steps."""

    def __init__(self, cluster, server, name):
        self.cluster, self.server, self.name = cluster, server, name
        self.tenants = {}
        self.store = NodeStore(name, capacity_bytes=1 << 30)
        self.srv = BlobServer(self.store, cluster.token,
                              tenant_of=self.tenants.get,
                              on_delete=self.tenants.pop)
        joined = server.dispatch({"op": "join", "worker": name,
                                  "resources": {"cpu": 1.0},
                                  "blob_host": self.srv.host,
                                  "blob_port": self.srv.port})
        assert joined["ok"] and joined["data_plane"] == "p2p"

    def auto_ack(self):
        """Wire the destination-side ack (what run_worker does)."""
        def ack(oid, tenant):
            self.tenants[oid] = tenant
            self.server.dispatch({"op": "migrated", "worker": self.name,
                                  "object": oid})
        self.srv.on_migrate = ack

    def add_blob(self, payload, oid: str):
        ref = ObjectRef(oid)
        blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
        self.store.put_blob(ref, blob)
        rec, _ = self.cluster.store.record(self.name, len(blob),
                                           ref_id=oid)
        return rec

    def poll(self):
        return self.server.dispatch({"op": "poll", "worker": self.name})

    def run_directives(self, moves, endpoint_override=None):
        """Source-side executor mirroring run_worker.run_migrations."""
        for mv in moves:
            ref = ObjectRef(str(mv["ref"]), int(mv.get("size", 0)))
            err, retryable = None, False
            try:
                blob = self.store.export_blob(ref)
            except KeyError as e:
                err = e
            if err is None:
                ep = endpoint_override or (mv["host"], int(mv["port"]))
                transport = TCPTransport(lambda _n, _ep=ep: _ep,
                                         self.cluster.token, self.name,
                                         timeout=2.0)
                err, retryable = push_with_retry(
                    transport, mv["node"], ref, blob,
                    TransferTicket.from_wire(mv["ticket"]))
            if err is not None:
                self.server.dispatch(
                    {"op": "migrate_failed", "worker": self.name,
                     "object": ref.id, "retryable": retryable,
                     "err": f"{type(err).__name__}: {err}"})

    def shutdown(self):
        self.srv.shutdown()


@pytest.fixture()
def proto(tmp_path):
    """A real head + three controllable peers; src holds one fat object."""
    cluster = SyndeoCluster(
        rendezvous=FileRendezvous(str(tmp_path)),
        scheduler_config=SchedulerConfig(enable_speculation=False,
                                         migration_timeout_s=0.4))
    server = HeadServer(cluster)
    server.attach()
    peers = {name: _Peer(cluster, server, name)
             for name in ("tcp-src", "tcp-d1", "tcp-d2")}
    ref = peers["tcp-src"].add_blob(b"\xab" * 64_000, "obj-fat")
    yield cluster, server, peers, ref
    for p in peers.values():
        p.shutdown()
    server.shutdown()
    cluster.shutdown()


def _finish_drain(cluster, server, wid, timeout=10.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        reply = server.dispatch({"op": "drain_status", "worker": wid})
        if reply.get("complete"):
            return True
        time.sleep(0.02)
    return False


def _assert_clean(cluster, server, ref, expect_on=None):
    check_invariants(cluster.store, expect_fetchable={ref.id},
                     scheduler=cluster.scheduler,
                     expect_zero_reconstructions=True)
    assert server.head_payload_bytes == 0
    # metrics truthfulness survives the same chaos: the head's exported
    # snapshot AND its Prometheus exposition must match ground truth
    check_metrics_conformance(
        cluster.store, cluster.scheduler,
        export=lambda: server.dispatch({"op": "metrics"}),
        prom=lambda: server.dispatch({"op": "metrics_text"})["text"])
    if expect_on is not None:
        locs = cluster.store.locations(ref)
        assert locs and locs <= expect_on, locs
        assert cluster.store.owner_of(ref) in locs


def test_proto_happy_path_direct_push_commits(proto):
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    for p in peers.values():
        p.auto_ack()
    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    got = src.poll()
    moves = got.get("migrations", [])
    assert len(moves) == 1 and moves[0]["ref"] == ref.id
    dst = moves[0]["node"]
    assert dst in ("tcp-d1", "tcp-d2")
    src.run_directives(moves)                  # push -> dest acks -> COMMIT
    assert _finish_drain(cluster, server, src.name)
    assert src.name not in cluster.scheduler.workers
    _assert_clean(cluster, server, ref, expect_on={dst})
    assert not src.store.has(ObjectRef(ref.id))    # source copy deleted
    assert cluster.store.stats["head_relayed_bytes"] == 0
    assert cluster.store.stats["relay_fallbacks"] == 0
    # destination actually serves the bytes
    assert cluster.store.get("head", ref) is not None


def test_proto_source_killed_before_push_loses_gracefully(proto):
    """Fault class: source kill. The move aborts with the node; nothing
    is stranded, ownership is not duplicated, and the directory honestly
    reports the object unfetchable (lineage's job from here)."""
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    assert src.poll().get("migrations")        # directive issued...
    with cluster._lock:                        # ...but the source dies
        cluster.scheduler.on_worker_failed(src.name, reason="injected")
    assert cluster.store.move_in_flight(ref.id) is None
    assert cluster.store.locations(ref) == set()
    check_invariants(cluster.store)
    assert server.head_payload_bytes == 0
    check_metrics_conformance(
        cluster.store, cluster.scheduler,
        export=lambda: server.dispatch({"op": "metrics"}),
        prom=lambda: server.dispatch({"op": "metrics_text"})["text"])


def test_proto_source_killed_after_push_recovers_copy(proto):
    """Fault class: source kill, but the push had already landed -- the
    destination's late ack is probed and registers the surviving copy
    (no lineage re-execution needed)."""
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    moves = src.poll().get("migrations", [])
    assert moves
    dst = moves[0]["node"]
    src.run_directives(moves)                  # push lands (no auto_ack)
    with cluster._lock:                        # source dies pre-ack
        cluster.scheduler.on_worker_failed(src.name, reason="injected")
    assert cluster.store.locations(ref) == set()
    # the destination worker finally sends its ack (late)
    reply = server.dispatch({"op": "migrated", "worker": dst,
                             "object": ref.id})
    assert reply["ok"] and reply.get("recovered")
    _assert_clean(cluster, server, ref, expect_on={dst})


def test_proto_destination_killed_pre_ack_replans(proto):
    """Fault class: destination kill. The push landed but the destination
    dies before acking -- the head aborts with the node and immediately
    re-plans onto the other survivor."""
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    moves = src.poll().get("migrations", [])
    assert moves
    first = moves[0]["node"]
    src.run_directives(moves)                  # push lands, ack withheld
    with cluster._lock:
        cluster.scheduler.on_worker_failed(first, reason="injected")
    other = next(n for n in ("tcp-d1", "tcp-d2") if n != first)
    peers[other].auto_ack()
    moves2 = src.poll().get("migrations", [])
    assert moves2 and moves2[0]["node"] == other    # re-planned directive
    src.run_directives(moves2)
    assert _finish_drain(cluster, server, src.name)
    _assert_clean(cluster, server, ref, expect_on={other})


def test_proto_dropped_commit_probed_into_commit(proto):
    """Fault class: dropped COMMIT. The push landed, the ack vanished --
    the migration-timeout sweep probes the destination and promotes the
    move to a COMMIT without moving a single byte again."""
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    moves = src.poll().get("migrations", [])
    assert moves
    dst = moves[0]["node"]
    src.run_directives(moves)                  # push lands; ack dropped
    receives = peers[dst].srv.stats["receives"]
    time.sleep(0.5)                            # > migration_timeout_s
    cluster.health_check()                     # sweep: probe + COMMIT
    assert _finish_drain(cluster, server, src.name)
    _assert_clean(cluster, server, ref, expect_on={dst})
    assert peers[dst].srv.stats["receives"] == receives    # no re-push
    assert cluster.store.stats["moves_committed"] >= 1


def test_proto_expired_ticket_replans_with_fresh_grant(proto):
    """Fault class: the migrate ticket expires mid-transfer. The
    destination refuses the push at the wire; the source's failure
    report ABORTs and the re-plan mints a fresh ticket that works."""
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    server.migrate_ttl_s = -1.0                # mint already-expired
    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    moves = src.poll().get("migrations", [])
    assert moves
    for p in peers.values():
        p.auto_ack()
    src.run_directives(moves)                  # push refused: SecurityError
    assert cluster.store.locations(ref) == {src.name}   # nothing moved
    server.migrate_ttl_s = 60.0
    # the failure report already re-planned -- drive the poll/push/report
    # loop like a real worker until a fresh-TTL mint lands the move
    done = False
    for _ in range(5):
        moves2 = src.poll().get("migrations", [])
        if moves2:
            src.run_directives(moves2)
        if _finish_drain(cluster, server, src.name, timeout=1.0):
            done = True
            break
    assert done, "expired-ticket re-plan never converged"
    dst_locs = cluster.store.locations(ref)
    _assert_clean(cluster, server, ref, expect_on=dst_locs)
    assert dst_locs <= {"tcp-d1", "tcp-d2"}
    assert cluster.store.stats["relay_fallbacks"] == 0


def test_proto_partition_degrades_to_relay_not_lineage(proto):
    """Fault class: partition. The source cannot reach the destination
    (retries exhausted) while the head can reach both -- the move
    degrades to the old head-relay copy, never to lineage."""
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    moves = src.poll().get("migrations", [])
    assert moves
    dst = moves[0]["node"]
    # black-hole the src->dst path: push goes to a dead endpoint
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        dead = s.getsockname()                 # bound, never accepting
        src.run_directives(moves, endpoint_override=dead)
    assert cluster.store.stats["relay_fallbacks"] == 1
    deadline = time.time() + 10
    while time.time() < deadline:              # relay thread lands the move
        if dst in cluster.store.locations(ref):
            break
        time.sleep(0.02)
    assert _finish_drain(cluster, server, src.name)
    _assert_clean(cluster, server, ref, expect_on={dst})
    assert cluster.store.stats["head_relayed_bytes"] > 0   # the price paid
    assert cluster.store.stats["reconstructions"] == 0     # never lineage


# ------------------------------------- full-stack 3-worker integration (TCP)


def _fat(i):
    return bytes([i % 256]) * 150_000


def test_three_worker_p2p_drain_zero_head_bytes(tmp_path):
    """Acceptance: drain of fat objects over real sockets with real
    workers driving the whole protocol themselves -- completes with
    head_payload_bytes == 0, zero head-relayed drain bytes, and the
    invariant checker passing."""
    cluster = SyndeoCluster(rendezvous=FileRendezvous(str(tmp_path)))
    server = HeadServer(cluster)
    server.attach()
    try:
        for i in range(3):
            threading.Thread(
                target=run_worker,
                args=(str(tmp_path), cluster.cluster_id, f"tcp-w{i}"),
                kwargs={"max_idle_s": 60.0}, daemon=True).start()
        deadline = time.time() + 20
        while time.time() < deadline and sum(
                1 for w in cluster.scheduler.workers.values()
                if w.alive) < 3:
            time.sleep(0.05)
        tasks = [cluster.submit(_fat, i) for i in range(4)]
        deadline = time.time() + 30
        while time.time() < deadline:
            with cluster._lock:
                states = {cluster.scheduler.graph.tasks[t.id].state
                          for t in tasks}
            if states == {TaskState.FINISHED}:
                break
            time.sleep(0.05)
        assert states == {TaskState.FINISHED}
        refs = [cluster.scheduler.graph.tasks[t.id].output for t in tasks]
        holders = {n for r in refs for n in cluster.store.locations(r)}
        assert holders and "head" not in holders
        victim = sorted(holders)[0]
        pre_fetchable = {r.id for r in refs}
        assert server.dispatch({"op": "drain", "worker": victim})["ok"]
        deadline = time.time() + 30
        while time.time() < deadline:
            with cluster._lock:
                gone = victim not in cluster.scheduler.workers
            if gone:
                break
            cluster.health_check()
            time.sleep(0.05)
        assert victim not in cluster.scheduler.workers, "drain stuck"
        # the tentpole claim: zero payload bytes through the head, for
        # the tasks AND the drain
        assert server.head_payload_bytes == 0
        assert cluster.store.stats["head_relayed_bytes"] == 0
        assert cluster.store.stats["relay_fallbacks"] == 0
        check_invariants(cluster.store, expect_fetchable=pre_fetchable,
                         scheduler=cluster.scheduler,
                         expect_zero_reconstructions=True)
        check_metrics_conformance(
            cluster.store, cluster.scheduler,
            export=lambda: server.dispatch({"op": "metrics"}),
            prom=lambda: server.dispatch({"op": "metrics_text"})["text"])
        for r in refs:
            locs = cluster.store.locations(r)
            assert locs and victim not in locs
        # values survived the drain byte-for-byte
        assert [cluster.get(r) for r in refs] == [_fat(i)
                                                  for i in range(4)]
    finally:
        server.shutdown()
        cluster.shutdown()
