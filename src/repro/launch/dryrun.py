import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell this lowers + compiles the real
train_step / serve_step against ShapeDtypeStruct stand-ins on the production
mesh (single-pod 16x16 = 256 chips, multi-pod 2x16x16 = 512 chips), prints
memory_analysis() (fits/doesn't fit) and cost_analysis(), and extracts the
scan-corrected roofline terms (repro.roofline). Results are written as JSON
artifacts under benchmarks/artifacts/dryrun/ -- EXPERIMENTS.md reads them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig, active_param_count, param_count
from repro.configs.shapes import ShapeConfig, applicable
from repro.launch.mesh import dp_degree, make_production_mesh
from repro.models import build_model, cache_specs, input_specs, shape_window
from repro.models.registry import make_batch
from repro.optim.optimizers import make_optimizer, warmup_cosine
from repro.roofline import (HloCostModel, dominant_term, model_flops,
                            roofline_fraction, roofline_terms)
from repro.sharding import axes as AX
from repro.sharding.rules import named_shardings, param_pspecs, zero1_extend
from repro.train.steps import make_init_state, make_train_step

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

# per-arch microbatch counts for train_4k (memory-driven; see DESIGN.md)
MICROBATCH = {
    "internvl2-76b": 16,
    "arctic-480b": 8,
    "qwen1.5-32b": 8,
    "stablelm-12b": 8,
    "granite-8b": 8,
    "llama3-8b": 8,
    "phi3.5-moe-42b-a6.6b": 8,
    "zamba2-2.7b": 4,
    "whisper-tiny": 2,
    "xlstm-350m": 2,
}


def _sds(x, sharding=None):
    return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sharding)


def _serve_cfg(cfg: ModelConfig) -> ModelConfig:
    """Serving shards weights over the DP axes too when the model-parallel
    shard alone would still be large (>2 GiB/chip): memory-bound decode
    streams weights anyway, so gathering them over ICI is the right trade."""
    if param_count(cfg) * 2 / 16 > 2 * 2**30:
        return cfg.replace(fsdp=True)
    return cfg


def _tree_sds(tree, shardings):
    return jax.tree.map(lambda t, s: _sds(t, s), tree, shardings)


def _leaf_sharding(path, leaf, cfg: ModelConfig, mesh, rules, dp_axes,
                   zero1: bool):
    from repro.sharding.axes import _guard_divisibility
    from repro.sharding.rules import logical_spec

    eff = dict(rules)
    if not cfg.fsdp:
        eff["fsdp"] = ()
    spec_logical = logical_spec(path, leaf, cfg)
    out = []
    for ax in spec_logical:
        if ax is None:
            out.append(None)
        else:
            phys = eff.get(ax, ())
            out.append(phys if phys else None)
    spec = _guard_divisibility(mesh, leaf.shape, P(*out))
    if zero1:
        spec = zero1_extend(spec, leaf.shape, mesh, dp_axes)
        spec = _guard_divisibility(mesh, leaf.shape, spec)
    return NamedSharding(mesh, spec)


def state_shardings(state_shapes, cfg: ModelConfig, mesh, rules,
                    dp_axes) -> Any:
    """NamedShardings for {"params", "opt", "step"} (ZeRO-1 on opt state)."""
    params_sh = jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_sharding(p, l, cfg, mesh, rules, dp_axes, False),
        state_shapes["params"])
    opt_sh = jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_sharding(p, l, cfg, mesh, rules, dp_axes, True),
        state_shapes["opt"])
    return {"params": params_sh, "opt": opt_sh,
            "step": NamedSharding(mesh, P())}


def grad_shardings(params_shapes, cfg: ModelConfig, mesh, rules, dp_axes):
    """ZeRO-2: fp32 grad accumulators take ZeRO-1-extended param shardings."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_sharding(p, l, cfg, mesh, rules, dp_axes, True),
        params_shapes)


def cache_shardings(cshapes, cfg: ModelConfig, mesh, rules, global_batch: int):
    """KV caches: (layers, batch, seq, cache_kv_heads, hd) -> shard batch over
    the DP axes and the *heads* dim over model (KV replication/padding in the
    configs guarantees divisibility). Recurrent states: shard batch only."""
    from repro.sharding.axes import _guard_divisibility
    batch_axes = rules.get("batch", ())
    model_axes = rules.get("model", ())
    head_dims = {cfg.cache_kv_heads, cfg.eff_kv_heads}

    def per_leaf(path, leaf):
        spec = [None] * len(leaf.shape)
        used_batch = used_model = False
        for i, dim in enumerate(leaf.shape):
            if i == 0 and len(leaf.shape) >= 4:
                continue  # stacked-layer dim stays unsharded
            if not used_batch and dim == global_batch:
                spec[i] = batch_axes
                used_batch = True
            elif (not used_model and used_batch and dim in head_dims
                  and i >= len(leaf.shape) - 2):
                spec[i] = model_axes
                used_model = True
        pspec = _guard_divisibility(mesh, leaf.shape, P(*spec))
        return NamedSharding(mesh, pspec)

    return jax.tree_util.tree_map_with_path(per_leaf, cshapes)


def batch_shardings(bspecs, mesh, rules):
    from repro.sharding.axes import _guard_divisibility
    batch_axes = rules.get("batch", ())

    def per_leaf(leaf):
        spec = [batch_axes] + [None] * (len(leaf.shape) - 1)
        pspec = _guard_divisibility(mesh, leaf.shape, P(*spec))
        return NamedSharding(mesh, pspec)

    return jax.tree.map(per_leaf, bspecs)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None):
    """Lower + compile one cell; returns (lowered, compiled, meta)."""
    overrides = overrides or {}
    import jax.numpy as _jnp
    import repro.models.layers as _L
    _L.FLASH_VJP = overrides.get("flash_vjp", True)
    _L.DEQUANT_DTYPE = _jnp.dtype(overrides.get("dequant_dtype", "float32"))
    _L.DECODE_BLOCK_K = overrides.get("decode_block_k", 1024)
    import repro.models.dense as _D
    _D.DIRECT_CACHE_DECODE = overrides.get("direct_cache", True)
    cfg = get_config(arch)
    for k, v in overrides.get("cfg", {}).items():
        cfg = cfg.replace(**{k: v})
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = AX.multi_pod_rules() if multi_pod else AX.single_pod_rules()
    rules.update(overrides.get("rules", {}))
    dp_axes = rules["batch"]
    n_groups = dp_degree(mesh)
    window = shape_window(cfg, shape)
    model = build_model(cfg, n_groups=n_groups, window=window)
    bspecs = input_specs(cfg, shape)

    with AX.axis_rules(mesh, rules):
        if shape.kind == "train":
            opt = make_optimizer(cfg.optimizer)
            mb = overrides.get("microbatches", MICROBATCH.get(arch, 4))
            # each microbatch must still cover every DP shard (>=1 seq/shard)
            mb = max(1, min(mb, shape.global_batch // n_groups))
            lr_fn = warmup_cosine(3e-4, 2000, 100000)
            state_shapes = jax.eval_shape(
                make_init_state(model, opt), jax.random.PRNGKey(0))
            st_sh = state_shardings(state_shapes, cfg, mesh, rules, dp_axes)
            # ZeRO-2: fp32 grad accumulator sharded over the DP axes
            g_sh = None if overrides.get("no_zero2") else grad_shardings(
                state_shapes["params"], cfg, mesh, rules, dp_axes)
            step_fn = make_train_step(
                model, opt, lr_fn, n_microbatches=mb, grad_shardings=g_sh,
                accum_dtype=overrides.get("accum_dtype", "float32"))
            b_sh = batch_shardings(bspecs, mesh, rules)
            args = (_tree_sds(state_shapes, st_sh),
                    jax.tree.map(lambda s, sh: _sds(s, sh), bspecs, b_sh))
            metric_sh = NamedSharding(mesh, P())
            out_sh = (st_sh, {"loss": metric_sh, "grad_norm": metric_sh,
                              "lr": metric_sh})
            lowered = jax.jit(step_fn, donate_argnums=(0,),
                              out_shardings=out_sh).lower(*args)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
            p_sh = named_shardings(params_shapes, _serve_cfg(cfg), mesh, rules)
            b_sh = batch_shardings(bspecs, mesh, rules)

            def prefill(params, batch):
                return model.prefill(params, batch)
            args = (_tree_sds(params_shapes, p_sh),
                    jax.tree.map(lambda s, sh: _sds(s, sh), bspecs, b_sh))
            # shard the emitted KV cache like the decode cells consume it
            out_shapes = jax.eval_shape(prefill, *args)
            logits_sh = NamedSharding(mesh, P())
            pc_sh = cache_shardings(out_shapes[1], cfg, mesh, rules,
                                    shape.global_batch)
            lowered = jax.jit(prefill,
                              out_shardings=(logits_sh, pc_sh)).lower(*args)
        else:  # decode
            params_shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
            p_sh = named_shardings(params_shapes, _serve_cfg(cfg), mesh, rules)
            cshapes = cache_specs(cfg, shape, window=window)
            c_sh = cache_shardings(cshapes, cfg, mesh, rules, shape.global_batch)
            b_sh = batch_shardings(bspecs, mesh, rules)

            def decode(params, cache, batch):
                return model.decode_step(params, cache, batch)
            args = (_tree_sds(params_shapes, p_sh),
                    _tree_sds(cshapes, c_sh),
                    jax.tree.map(lambda s, sh: _sds(s, sh), bspecs, b_sh))
            logits_sh = NamedSharding(mesh, P())  # (B,1,V) is tiny; B may be 1
            lowered = jax.jit(decode, donate_argnums=(1,),
                              out_shardings=(logits_sh, c_sh)).lower(*args)

    t0 = time.time()
    compiled = lowered.compile()
    meta = {"compile_s": time.time() - t0, "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": 512 if multi_pod else 256}
    return lowered, compiled, meta


def analyze(compiled, cfg: ModelConfig, shape: ShapeConfig, n_devices: int) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    cm = HloCostModel(compiled.as_text())
    cost = cm.entry_cost()
    terms = roofline_terms(cost)
    mf = model_flops(cfg, shape)
    hlo_global = cost.flops * n_devices
    mem_gb = (ma.argument_size_in_bytes + ma.output_size_in_bytes
              + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30
    return {
        "memory": {
            "argument_gb": ma.argument_size_in_bytes / 2**30,
            "output_gb": ma.output_size_in_bytes / 2**30,
            "temp_gb": ma.temp_size_in_bytes / 2**30,
            "alias_gb": ma.alias_size_in_bytes / 2**30,
            "peak_per_device_gb": mem_gb,
            "fits_16gb": bool(mem_gb < 16.0),
        },
        "cost_analysis": {"flops_raw": ca.get("flops"),
                          "bytes_raw": ca.get("bytes accessed")},
        "roofline": {
            **terms,
            "dominant": dominant_term(terms),
            "roofline_fraction": roofline_fraction(terms),
            "model_flops_global": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "collectives": cost.collectives,
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False,
             overrides: Optional[Dict[str, Any]] = None,
             tag: str = "baseline") -> Dict[str, Any]:
    mesh_name = "multipod" if multi_pod else "singlepod"
    out_dir = ART_DIR / tag / mesh_name
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file = out_dir / f"{arch}__{shape_name}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "params": param_count(cfg), "active_params": active_param_count(cfg),
    }
    if not applicable(cfg.family, cfg.sub_quadratic, shape_name):
        record["status"] = "skipped"
        record["reason"] = ("long_500k requires sub-quadratic attention; "
                            f"{arch} is full-attention (DESIGN.md)")
        out_file.write_text(json.dumps(record, indent=1))
        print(f"SKIP {arch} x {shape_name}: {record['reason']}")
        return record
    try:
        t0 = time.time()
        lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod,
                                             overrides)
        record.update(meta)
        record.update(analyze(compiled, cfg, shape,
                              n_devices=meta["n_devices"]))
        record["status"] = "ok"
        record["total_s"] = time.time() - t0
        r = record["roofline"]
        print(f"OK   {arch} x {shape_name} [{mesh_name}] "
              f"compile={meta['compile_s']:.1f}s "
              f"mem={record['memory']['peak_per_device_gb']:.2f}GiB "
              f"terms(c/m/x)={r['compute_s']:.3e}/{r['memory_s']:.3e}/"
              f"{r['collective_s']:.3e}s dom={r['dominant']} "
              f"frac={r['roofline_fraction']:.3f}")
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()
        print(f"FAIL {arch} x {shape_name} [{mesh_name}]: {record['error']}")
    out_file.write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_fail = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mp, force=args.force, tag=args.tag)
                if rec["status"] == "error":
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"\ndone: {n_ok} ok/skip, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
