"""xlstm-350m  [arXiv:2405.04517]
24L d_model=1024 4H d_ff=0 vocab=50304. sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM).
Fully recurrent, O(1) decode state => long_500k runs."""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8),
    sub_quadratic=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    xlstm=XLSTMConfig(slstm_every=2),
    sub_quadratic=True,
    tie_embeddings=True,
)
