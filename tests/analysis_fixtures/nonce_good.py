"""known-good: replayed envelopes are rejected by the nonce cache."""
from repro.core.security import NonceCache, open_sealed

_NONCES = NonceCache()


def read_reply(token, envelope):
    return open_sealed(token, envelope, nonce_cache=_NONCES)
