"""zamba2-style hybrid model: mamba2 backbone + one *shared* attention+FFN
block applied every `attn_every` layers.

Layout: the layer stack is a scan over `nb = n_layers // attn_every`
super-blocks; each super-block is an inner scan over `attn_every` mamba2
blocks followed by the shared attention block (parameters captured, not
scanned -- they are shared across applications, exactly as in zamba2).
Each application keeps its own KV cache slice (nb-leading cache arrays).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.sharding.axes import constrain

F32 = jnp.float32


def _nb(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.hybrid.attn_every == 0
    return cfg.n_layers // cfg.hybrid.attn_every


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ka, kf = jax.random.split(key, 4)
    nb, k_per = _nb(cfg), cfg.hybrid.attn_every
    mkeys = jax.random.split(km, nb * k_per).reshape(nb, k_per, 2)
    mamba = jax.vmap(jax.vmap(lambda k: M.init_mamba_block(k, cfg, dtype)))(mkeys)
    k1, k2, k3, k4 = jax.random.split(ka, 4)
    std = cfg.d_model ** -0.5
    shared = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, cfg.qkv_bias, dtype,
                                 cfg.pad_heads_to, cfg.pad_kv_heads_to),
        "mlp": {
            "w1": (jax.random.normal(k2, (cfg.d_model, cfg.d_ff)) * std).astype(dtype),
            "w3": (jax.random.normal(k3, (cfg.d_model, cfg.d_ff)) * std).astype(dtype),
            "w2": (jax.random.normal(k4, (cfg.d_ff, cfg.d_model)) * std).astype(dtype),
        },
    }
    return {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype,
                                  cfg.tie_embeddings, cfg.padded_vocab),
        "mamba": mamba,
        "shared_attn": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def _shared_attn_fwd(sp, x, positions, cfg, window):
    h, kv = L.attention(sp["attn"], L.rms_norm(x, sp["ln1"], cfg.norm_eps),
                        positions, cfg, causal=True, window=window)
    x = x + h
    y = L.swiglu(L.rms_norm(x, sp["ln2"], cfg.norm_eps),
                 sp["mlp"]["w1"], sp["mlp"]["w3"], sp["mlp"]["w2"])
    return x + y, kv


def backbone_fwd(params, x, positions, cfg: ModelConfig, *,
                 window: Optional[int] = None, remat: bool = True,
                 collect_kv: bool = False):
    sp = params["shared_attn"]

    def super_block(carry, mp_sb):
        def inner(c, mp):
            return M.mamba_fwd(mp, c, cfg), None
        y, _ = jax.lax.scan(inner, carry, mp_sb)
        y, kv = _shared_attn_fwd(sp, y, positions, cfg, window)
        if collect_kv:
            k, v = kv
            return y, (k, v)
        return y, None

    if remat:
        super_block = jax.checkpoint(super_block, prevent_cse=False)
    x, kvs = jax.lax.scan(super_block, x, params["mamba"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), kvs


def lm_loss(params, batch, cfg: ModelConfig, *, n_groups: int = 1):
    tokens, targets = batch["tokens"], batch["targets"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = L.embed(params["embed"], tokens)
    x, _ = backbone_fwd(params, x, positions, cfg)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    loss = L.softmax_xent(logits, targets, batch.get("loss_mask"))
    return loss, {"xent": loss}


# ----------------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               window: Optional[int] = None):
    nb, k_per = _nb(cfg), cfg.hybrid.attn_every
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    W = min(window, max_len) if window else max_len
    conv_bufs, ssm = [], []
    cb, s = M.init_mamba_state(cfg, batch)
    stack = lambda a, n: jnp.broadcast_to(a, (n,) + a.shape)
    return {
        "k": jnp.zeros((nb, batch, W, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((nb, batch, W, cfg.n_kv_heads, hd), dtype),
        "conv": stack(stack(cb, k_per), nb),
        "ssm": stack(stack(s, k_per), nb),
    }


def lm_prefill(params, batch, cfg: ModelConfig, *, n_groups: int = 1,
               window: Optional[int] = None):
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = L.embed(params["embed"], tokens)
    sp = params["shared_attn"]

    def super_block(carry, mp_sb):
        x_c = carry

        def inner(c, mp):
            y, st = M.mamba_fwd(mp, c, cfg, return_state=True)
            return y, st
        y, (convs, ssms) = jax.lax.scan(inner, x_c, mp_sb)
        y, kv = _shared_attn_fwd(sp, y, positions, cfg, window)
        return y, (kv[0], kv[1], convs, ssms)

    x, (ks, vs, convs, ssms) = jax.lax.scan(super_block, x, params["mamba"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:, :], cfg.vocab_size)
    # decode-ready cache: attention KV per superblock application + the
    # recurrent (conv/ssm) states at position T for every mamba layer
    cache = {"k": ks, "v": vs, "conv": convs, "ssm": ssms}
    return logits, cache


def lm_decode_step(params, cache, batch, cfg: ModelConfig, *, n_groups: int = 1,
                   window: Optional[int] = None):
    """One-token decode. cache: k/v (nb,B,W,H,hd), conv (nb,k,...), ssm (nb,k,...)."""
    tokens, pos = batch["tokens"], batch["positions"]
    B = tokens.shape[0]
    x = L.embed(params["embed"], tokens)
    sp = params["shared_attn"]
    hd = cfg.resolved_head_dim
    W = cache["k"].shape[2]

    def super_block(carry, scanned):
        xc = carry
        mp_sb, conv_sb, ssm_sb, ck, cv = scanned

        def inner(c, mps):
            mp, cb, s = mps
            y, (cb2, s2) = M.mamba_decode(mp, c, (cb, s), cfg)
            return y, (cb2, s2)
        xc, (conv2, ssm2) = jax.lax.scan(inner, xc, (mp_sb, conv_sb, ssm_sb))

        # shared attention with rolling cache slot = pos % W
        xn = L.rms_norm(xc, sp["ln1"], cfg.norm_eps)
        q = jnp.einsum("btd,dq->btq", xn, sp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
        k = jnp.einsum("btd,dk->btk", xn, sp["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
        v = jnp.einsum("btd,dk->btk", xn, sp["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k = L.rope(k, pos[:, None], cfg.rope_theta)
        slot = (pos % W)
        bidx = jnp.arange(B)
        ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
        valid = jnp.minimum(pos + 1, W)
        o = L.flash_attention_ref(q, ck, cv, causal=False, valid_len=valid,
                                  block_q=1, block_k=min(1024, W))
        o = o.reshape(B, 1, cfg.n_heads * hd)
        xc = xc + jnp.einsum("btq,qd->btd", o, sp["attn"]["wo"])
        y = L.swiglu(L.rms_norm(xc, sp["ln2"], cfg.norm_eps),
                     sp["mlp"]["w1"], sp["mlp"]["w3"], sp["mlp"]["w2"])
        return xc + y, (conv2, ssm2, ck, cv)

    xs = (params["mamba"], cache["conv"], cache["ssm"], cache["k"], cache["v"])
    x, (conv_n, ssm_n, k_n, v_n) = jax.lax.scan(super_block, x, xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    return logits, {"k": k_n, "v": v_n, "conv": conv_n, "ssm": ssm_n}
