"""syndeo-lint pass 2: auth-before-use taint.

SYN-A001  data read straight off a socket (``recv``/``readline``/
          ``recv_frame``) reaches a store mutation (``put_blob``,
          ``import_blob``, ``record`` ...) without flowing through a
          sanitizer (``open_sealed``, ``TransferTicket.verify``,
          ``_verify``).  Intra-procedural, statement-ordered.

SYN-A002  an op-dispatch branch of a ticket-checking server (a class
          that defines ``_verify``) mutates the store before any
          ``_verify``/``.verify()`` call in that branch.

SYN-A003  ``open_sealed()`` called without a ``nonce_cache=`` keyword:
          the envelope's age window alone leaves it replayable.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.model import CodeModel, Finding, calls_in

SOURCE_NAMES = {"recv", "readline", "recvfrom", "recv_frame"}
SANITIZER_NAMES = {"open_sealed", "verify", "_verify"}
STORE_MUTATORS = {"put_blob", "import_blob", "delete", "put", "record",
                  "note_replica", "migrate"}


def check_taint(model: CodeModel) -> List[Finding]:
    findings: List[Finding] = []
    defines_open_sealed = {
        fn.file for fn in model.functions.values()
        if fn.name == "open_sealed" and fn.class_name is None}
    for fn in model.functions.values():
        findings.extend(_flow_taint(fn))
        if fn.file not in defines_open_sealed:
            findings.extend(_nonce_cache_required(fn))
    findings.extend(_branch_auth(model))
    return findings


def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _is_store_mutation(call: ast.Call) -> bool:
    f = call.func
    if not isinstance(f, ast.Attribute) or f.attr not in STORE_MUTATORS:
        return False
    try:
        recv = ast.unparse(f.value).lower()
    except Exception:  # pragma: no cover
        return False
    return "store" in recv


# -- SYN-A001: source -> sink flow ---------------------------------------


def _expr_tainted(e: ast.AST, tainted: Set[str]) -> bool:
    if isinstance(e, ast.Lambda):
        return False
    if isinstance(e, ast.Call):
        name = _call_name(e)
        if name in SANITIZER_NAMES:
            return False  # sanitizer output is clean by definition
        if name in SOURCE_NAMES:
            return True
        return any(_expr_tainted(c, tainted)
                   for c in ast.iter_child_nodes(e))
    if isinstance(e, ast.Name):
        return e.id in tainted
    return any(_expr_tainted(c, tainted)
               for c in ast.iter_child_nodes(e))


def _flow_taint(fn) -> List[Finding]:
    findings: List[Finding] = []
    tainted: Set[str] = set()
    _flow_block(fn, list(getattr(fn.node, "body", [])), tainted,
                findings)
    return findings


def _flow_block(fn, stmts: List[ast.stmt], tainted: Set[str],
                findings: List[Finding]) -> None:
    for st in stmts:
        _flow_stmt(fn, st, tainted, findings)


def _check_sinks(fn, node: ast.AST, tainted: Set[str],
                 findings: List[Finding]) -> None:
    for call in calls_in(node):
        if not _is_store_mutation(call):
            continue
        hot = [a for a in list(call.args)
               + [k.value for k in call.keywords]
               if _expr_tainted(a, tainted)]
        if hot:
            findings.append(Finding(
                "SYN-A001", fn.file, call.lineno, fn.qualname,
                f"unverified socket data reaches store mutation "
                f"{_call_name(call)}() (argument "
                f"{ast.unparse(hot[0])!r} is tainted)"))


def _flow_stmt(fn, st: ast.stmt, tainted: Set[str],
               findings: List[Finding]) -> None:
    if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                       ast.ClassDef)):
        return
    if isinstance(st, ast.Assign):
        _check_sinks(fn, st.value, tainted, findings)
        is_hot = _expr_tainted(st.value, tainted)
        for tgt in st.targets:
            if isinstance(tgt, ast.Name):
                if is_hot:
                    tainted.add(tgt.id)
                else:
                    tainted.discard(tgt.id)
            elif isinstance(tgt, ast.Tuple):
                for el in tgt.elts:
                    if isinstance(el, ast.Name):
                        if is_hot:
                            tainted.add(el.id)
                        else:
                            tainted.discard(el.id)
            elif (isinstance(tgt, ast.Subscript)
                  and isinstance(tgt.value, ast.Name) and is_hot):
                tainted.add(tgt.value.id)  # d[k] = hot taints d
        return
    if isinstance(st, ast.AnnAssign) and st.value is not None:
        _check_sinks(fn, st.value, tainted, findings)
        if isinstance(st.target, ast.Name):
            if _expr_tainted(st.value, tainted):
                tainted.add(st.target.id)
            else:
                tainted.discard(st.target.id)
        return
    if isinstance(st, ast.AugAssign):
        _check_sinks(fn, st.value, tainted, findings)
        if (isinstance(st.target, ast.Name)
                and _expr_tainted(st.value, tainted)):
            tainted.add(st.target.id)
        return
    if isinstance(st, ast.If):
        _check_sinks(fn, st.test, tainted, findings)
        t_body = set(tainted)
        t_else = set(tainted)
        _flow_block(fn, st.body, t_body, findings)
        _flow_block(fn, st.orelse, t_else, findings)
        tainted |= t_body | t_else  # conservative merge
        return
    if isinstance(st, (ast.While, ast.For)):
        head = st.test if isinstance(st, ast.While) else st.iter
        _check_sinks(fn, head, tainted, findings)
        if (isinstance(st, ast.For) and isinstance(st.target, ast.Name)
                and _expr_tainted(st.iter, tainted)):
            tainted.add(st.target.id)
        # two passes: loop bodies can taint names used earlier in the body
        t_loop = set(tainted)
        _flow_block(fn, st.body, t_loop, [])
        tainted |= t_loop
        _flow_block(fn, st.body, tainted, findings)
        _flow_block(fn, st.orelse, tainted, findings)
        return
    if isinstance(st, (ast.With, ast.AsyncWith)):
        for item in st.items:
            _check_sinks(fn, item.context_expr, tainted, findings)
        _flow_block(fn, st.body, tainted, findings)
        return
    if isinstance(st, ast.Try):
        _flow_block(fn, st.body, tainted, findings)
        for h in st.handlers:
            _flow_block(fn, h.body, tainted, findings)
        _flow_block(fn, st.orelse, tainted, findings)
        _flow_block(fn, st.finalbody, tainted, findings)
        return
    for child in ast.iter_child_nodes(st):
        if isinstance(child, ast.expr):
            _check_sinks(fn, child, tainted, findings)


# -- SYN-A002: verify-before-mutate in dispatch branches -----------------


def _branch_auth(model: CodeModel) -> List[Finding]:
    findings: List[Finding] = []
    for cls_list in model.classes.values():
        for ci in cls_list:
            if "_verify" not in ci.methods:
                continue
            for mname, method in ci.methods.items():
                if mname == "_verify":
                    continue
                findings.extend(_check_dispatch(method))
    return findings


def _op_branches(node: ast.AST) -> List[ast.If]:
    """``if op == "x":`` / ``if hdr.get("op") == "x":`` branch tests."""
    opvars: Set[str] = set()
    for st in ast.walk(node):
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)
                and _reads_op(st.value)):
            opvars.add(st.targets[0].id)
    out: List[ast.If] = []
    for st in ast.walk(node):
        if isinstance(st, ast.If) and _is_op_test(st.test, opvars):
            out.append(st)
    return out


def _reads_op(e: ast.AST) -> bool:
    if (isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute)
            and e.func.attr == "get" and e.args
            and isinstance(e.args[0], ast.Constant)
            and e.args[0].value == "op"):
        return True
    if (isinstance(e, ast.Subscript)
            and isinstance(e.slice, ast.Constant)
            and e.slice.value == "op"):
        return True
    return False


def _is_op_test(test: ast.AST, opvars: Set[str]) -> bool:
    if not (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.ops[0], (ast.Eq, ast.In))):
        return False
    left = test.left
    if isinstance(left, ast.Name) and left.id in opvars:
        return True
    return _reads_op(left)


def _check_dispatch(method) -> List[Finding]:
    findings: List[Finding] = []
    for branch in _op_branches(method.node):
        verified = False
        for st in branch.body:
            for call in calls_in(st):
                name = _call_name(call)
                if name in ("verify", "_verify"):
                    verified = True
                elif _is_store_mutation(call) and not verified:
                    findings.append(Finding(
                        "SYN-A002", method.file, call.lineno,
                        method.qualname,
                        f"store mutation {name}() in op branch "
                        f"before any _verify()/ticket.verify() call"))
    return findings


# -- SYN-A003: open_sealed without a nonce cache -------------------------


def _nonce_cache_required(fn) -> List[Finding]:
    findings: List[Finding] = []
    for call in calls_in(fn.node):
        if _call_name(call) != "open_sealed":
            continue
        if any(kw.arg == "nonce_cache" for kw in call.keywords):
            continue
        findings.append(Finding(
            "SYN-A003", fn.file, call.lineno, fn.qualname,
            "open_sealed() without nonce_cache=: sealed envelope is "
            "replayable inside its freshness window"))
    return findings
