"""qwen1.5-32b  [hf:Qwen/Qwen1.5-* family]
64L d_model=5120 40H (MHA kv=40) d_ff=27392 vocab=152064, QKV bias.
decode_32k uses an int8 KV cache: bf16 would need ~21 GB/chip (64L x 32k x
40 kv-heads x 128 hd x 128 batch over 256 chips) > 16 GB HBM."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    kv_cache_dtype="int8",
    pad_heads_to=48,
    pad_kv_heads_to=48,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab_size=256,
    qkv_bias=True,
    kv_cache_dtype="int8",
    pad_heads_to=6,
    pad_kv_heads_to=6,
)
