"""Per-architecture smoke tests (reduced same-family configs, CPU):
one train step (loss finite, grads flow) + one decode step, and for the
dense family a prefill/decode-vs-full-forward greedy consistency check."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import ShapeConfig
from repro.models import build_model
from repro.models.registry import make_batch

SHAPE = ShapeConfig("smoke", "train", 32, 4)


@pytest.fixture(scope="module")
def smoke_models():
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, n_groups=1)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SHAPE)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0.0 and jnp.isfinite(gnorm), f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, n_groups=1)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 4, 32
    cache = model.init_cache(B) if cfg.family == "ssm" else model.init_cache(B, S)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
             "positions": jnp.zeros((B,), jnp.int32)}
    logits, cache2 = jax.jit(model.decode_step)(params, cache, batch)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen1.5-32b", "whisper-tiny",
                                  "zamba2-2.7b", "xlstm-350m"])
def test_decode_matches_full_forward(arch):
    """Greedy decode through the cache must equal argmax of the full forward
    at the same position -- catches cache indexing/rope/dequant bugs."""
    cfg = get_config(arch, smoke=True)
    if cfg.kv_cache_dtype == "int8":
        cfg = cfg.replace(kv_cache_dtype="bfloat16")  # exactness for the test
    model = build_model(cfg, n_groups=1)
    params = model.init_params(jax.random.PRNGKey(1))
    B, T = 2, 16
    key = jax.random.PRNGKey(2)
    batch = make_batch(cfg, ShapeConfig("t", "prefill", T, B), key)

    logits_pref, cache = jax.jit(model.prefill)(params, batch)

    # full-forward logits at the last position
    tb = dict(batch)
    tb["targets"] = batch["tokens"]
    # compute full logits through the loss path's forward
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import dense as D
        from repro.models import layers as L
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        x = L.embed(params["embed"], batch["tokens"])
        x = D._inject_frontend(params, batch, x, cfg)
        x, _ = D.backbone_fwd(params, x, positions, cfg, n_groups=1,
                              remat=False)
        full_logits = L.unembed(params["embed"], x, cfg.vocab_size)
        ref_next = jnp.argmax(full_logits[:, -1], -1)
        got_next = jnp.argmax(logits_pref[:, -1], -1)
        assert bool(jnp.all(ref_next == got_next)), arch

    # one decode step after prefill must be finite + correctly positioned
    if cfg.family == "ssm":
        cache = model.init_cache(B)
        # rebuild states by decoding the prompt token-by-token
        pos = jnp.zeros((B,), jnp.int32)
        for t in range(T):
            step = {"tokens": batch["tokens"][:, t:t + 1], "positions": pos}
            dec_logits, cache = jax.jit(model.decode_step)(params, cache, step)
            pos = pos + 1
        # final-step logits must match the parallel forward's last position
        logits_par, _ = jax.jit(model.prefill)(params, batch)
        assert jnp.allclose(dec_logits[:, 0], logits_par[:, -1], atol=2e-2,
                            rtol=2e-2), arch
    else:
        step = {"tokens": jnp.argmax(logits_pref[:, -1], -1)[:, None].astype(jnp.int32),
                "positions": jnp.full((B,), T, jnp.int32)}
        dec_logits, _ = jax.jit(model.decode_step)(params, cache, step)
        assert bool(jnp.all(jnp.isfinite(dec_logits.astype(jnp.float32))))


def test_zamba2_decode_consistency_with_prefill_path():
    """Hybrid arch: stepwise decode from scratch equals the parallel
    (chunked-SSD) forward at the final position."""
    cfg = get_config("zamba2-2.7b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    B, T = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, T), 0,
                                cfg.vocab_size, jnp.int32)
    logits_par, _ = jax.jit(model.prefill)(params, {"tokens": tokens})

    cache = model.init_cache(B, T)
    pos = jnp.zeros((B,), jnp.int32)
    dec = None
    step_fn = jax.jit(model.decode_step)
    for t in range(T):
        dec, cache = step_fn(params, cache, {"tokens": tokens[:, t:t + 1],
                                             "positions": pos})
        pos = pos + 1
    assert jnp.allclose(dec[:, 0].astype(jnp.float32),
                        logits_par[:, -1].astype(jnp.float32),
                        atol=3e-2, rtol=3e-2)


def test_vocab_padding_is_masked():
    cfg = get_config("whisper-tiny", smoke=True).replace(vocab_size=250)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SHAPE)
    loss, _ = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss)
    # padded logit rows must be -1e9
    assert cfg.padded_vocab == 256


def test_padded_heads_are_exact():
    """Head padding (qwen/arctic-style) must not change the computed loss:
    init_attention places identically-seeded real weights into the padded
    layout with zero pad heads, preserving the GQA group mapping."""
    base = get_config("llama3-8b", smoke=True)     # 4 q heads, 2 kv heads
    padded = base.replace(pad_heads_to=8)          # R 2 -> 4, grouped pad
    m0, m1 = build_model(base), build_model(padded)
    p0 = m0.init_params(jax.random.PRNGKey(7))
    p1 = m1.init_params(jax.random.PRNGKey(7))
    batch = make_batch(base, SHAPE, jax.random.PRNGKey(8))
    l0, _ = jax.jit(m0.loss)(p0, batch)
    l1, _ = jax.jit(m1.loss)(p1, batch)
    assert jnp.allclose(l0, l1, atol=2e-3, rtol=1e-4), (l0, l1)
