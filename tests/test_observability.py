"""Observability plane: instruments, exposition, and chaos-verified
truthfulness.

Four layers under test:

  1. Instrument algebra (property-tested): histogram merge is
     associative and commutative, wire deltas round-trip exactly, and
     quantile estimates are bucket-bounded -- never below the exact
     order statistic and at most one bucket above it. These properties
     are what make worker-side collection safe: deltas can arrive in
     any order and fold into any intermediate aggregate.
  2. Exposition (golden-tested): the Prometheus text renderer's label
     escaping and `_bucket`/`_sum`/`_count` layout, plus the Grafana
     dashboard JSON whose panel exprs must reference exported names.
  3. Pipeline truthfulness: sim-driven waves produce sojourn histograms
     whose counts equal the scheduler's own finished counters, checked
     by the same `check_metrics_conformance` every chaos scenario ends
     with (tests/README.md, "Metrics conformance").
  4. The exit flush (regression): a worker's deltas accrued between its
     last poll and its death -- drain pushes, final poll latencies --
     are flushed during the drain handshake. With the flush disabled
     the conformance checker MUST catch the head-vs-reality divergence,
     proving the checker would have caught the original bug.
"""
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from _invariants import check_metrics_conformance
from repro.core import (SchedulerConfig, SimCluster, SimCostModel,
                        SyndeoCluster, TaskSpec, TaskState)
from repro.core.metrics import (DEPTH_BUCKETS, Histogram, MetricsHub,
                                MetricsRegistry, TimeSeries, log_buckets,
                                parse_prometheus, render_dashboards,
                                render_prometheus)
from repro.core.rendezvous import FileRendezvous
from repro.core.worker import HeadServer, run_worker

# a deliberately coarse bound set keeps the property tests readable
_BOUNDS = log_buckets(0.001, 16.0)


def _hist(values, bounds=_BOUNDS) -> Histogram:
    h = Histogram(bounds)
    for v in values:
        h.observe(v)
    return h


_vals = st.lists(st.floats(min_value=0.0, max_value=100.0),
                 min_size=0, max_size=50)


# ------------------------------------------------- instrument algebra


@settings(max_examples=50, deadline=None)
@given(_vals, _vals, _vals)
def test_histogram_merge_associative_commutative(xs, ys, zs):
    a, b, c = _hist(xs), _hist(ys), _hist(zs)
    assert a.merge(b) == b.merge(a)
    assert a.merge(b).merge(c) == a.merge(b.merge(c))
    # merging is lossless aggregation: same state as observing everything
    assert a.merge(b).merge(c) == _hist(list(xs) + list(ys) + list(zs))
    # and pure: the operands were not mutated
    assert a == _hist(xs) and b == _hist(ys)


@settings(max_examples=50, deadline=None)
@given(_vals, _vals)
def test_histogram_delta_roundtrip(xs, ys):
    """The worker wire path: `to_delta` against the last confirmed base,
    `apply_delta` folding it in head-side, must reconstruct the full
    state exactly -- regardless of how observations split across polls."""
    base = _hist(xs)
    cur = _hist(xs)
    for v in ys:
        cur.observe(v)
    delta = cur.to_delta(base)
    assert delta["count"] == len(ys)
    folded = _hist(xs)
    folded.apply_delta(delta)
    assert folded == cur
    # sparse: only changed buckets ride the wire
    assert all(int(v) != 0 for v in delta["counts"].values())


@settings(max_examples=50, deadline=None)
@given(_vals, st.integers(1, 99))
def test_quantile_estimates_are_bucket_bounded(xs, pct):
    """`quantile(q)` returns the upper bound of the bucket holding the
    exact order statistic: never below it, at most one bucket above."""
    h = _hist(xs)
    q = pct / 100.0
    est = h.quantile(q)
    if not xs:
        assert est == 0.0
        return
    import math
    exact = sorted(xs)[max(1, math.ceil(q * len(xs))) - 1]
    top = len(h.bounds) - 1
    assert est == h.bounds[min(h.bucket_index(exact), top)]
    # bucket-bounded from below (overflow clamps to the top bound)
    assert est >= min(exact, h.bounds[top])


def test_histogram_rejects_mismatched_bounds_and_bad_quantiles():
    a = Histogram(log_buckets(0.001, 1.0))
    b = Histogram(log_buckets(0.002, 1.0))
    with pytest.raises(AssertionError):
        a.merge(b)
    with pytest.raises(AssertionError):
        a.to_delta(b)
    assert Histogram(_BOUNDS).quantile(0.99) == 0.0     # empty
    h = _hist([0.5])
    assert h.quantile(-1.0) == h.quantile(0.0) == h.quantile(2.0) \
        == h.quantile(1.0)                              # q is clamped


def test_registry_keys_by_labels_and_rejects_kind_clashes():
    reg = MetricsRegistry()
    reg.counter("c", tenant="a").inc(2)
    reg.counter("c", tenant="b").inc(5)
    assert reg.counter("c", tenant="a").value == 2
    fam = reg.family("c")
    assert {dict(k)["tenant"] for k in fam} == {"a", "b"}
    with pytest.raises(AssertionError):
        reg.gauge("c", tenant="a")      # a counter already owns this name
    # histogram bounds resolve from the well-known-name table
    depth = reg.histogram("syndeo_router_queue_depth")
    assert depth.bounds == DEPTH_BUCKETS


def test_timeseries_ring_buffer_wraps():
    ts = TimeSeries(capacity=4)
    for i in range(6):
        ts.record(float(i), float(i * 10))
    assert len(ts) == 4
    assert ts.points() == [(2.0, 20.0), (3.0, 30.0), (4.0, 40.0),
                           (5.0, 50.0)]
    assert ts.latest == (5.0, 50.0)


def test_hub_ingest_records_scalars_and_labelled_dicts():
    hub = MetricsHub(capacity=8)
    hub.ingest(1.0, {"ok": True, "backlog": 3,
                     "syndeo_link_bytes": {"a->b": 100}})
    hub.ingest(2.0, {"ok": True, "backlog": 5,
                     "syndeo_link_bytes": {"a->b": 250}})
    assert hub.history("backlog") == [(1.0, 3.0), (2.0, 5.0)]
    assert hub.history("syndeo_link_bytes", "a->b") == [(1.0, 100.0),
                                                        (2.0, 250.0)]
    assert hub.history("ok") == []      # health flag is not a series


# ------------------------------------------------- exposition (golden)


def test_prometheus_exposition_golden():
    """Byte-exact layout: TYPE lines, cumulative `_bucket{le=...}` with
    the `+Inf` closer, `_sum`/`_count`, label escaping of backslash and
    quote, dict-valued flat metrics under a `key` label."""
    reg = MetricsRegistry()
    reg.counter("acme_requests", path="a\\b", tenant='t"1"').inc(3)
    reg.gauge("acme_depth").set(2.5)
    h = reg.histogram("acme_lat", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    flat = {"ok": True, "workers": 2,
            "syndeo_link_bytes": {"w0->w1": 1024}}
    golden = (
        '# TYPE acme_depth gauge\n'
        'acme_depth 2.5\n'
        '# TYPE acme_lat histogram\n'
        'acme_lat_bucket{le="0.1"} 1\n'
        'acme_lat_bucket{le="1"} 2\n'
        'acme_lat_bucket{le="+Inf"} 3\n'
        'acme_lat_sum 5.55\n'
        'acme_lat_count 3\n'
        '# TYPE acme_requests counter\n'
        'acme_requests{path="a\\\\b",tenant="t\\"1\\""} 3\n'
        '# TYPE syndeo_link_bytes gauge\n'
        'syndeo_link_bytes{key="w0->w1"} 1024\n'
        '# TYPE workers gauge\n'
        'workers 2\n')
    assert render_prometheus(reg, flat=flat) == golden
    # the read-back parser agrees with what was rendered
    parsed = parse_prometheus(golden)
    assert parsed[("acme_lat_count", "")] == 3.0
    assert parsed[("acme_lat_bucket", '{le="+Inf"}')] == 3.0
    assert parsed[("syndeo_link_bytes", '{key="w0->w1"}')] == 1024.0
    assert parsed[("workers", "")] == 2.0


def test_prometheus_escapes_newlines_and_sanitizes_names():
    reg = MetricsRegistry()
    reg.gauge("weird metric!", who="a\nb").set(1)
    text = render_prometheus(reg)
    assert 'weird_metric_{who="a\\nb"} 1\n' in text
    assert "\na\n" not in text          # the raw newline never leaks


def test_dashboards_reference_exported_metric_names():
    boards = render_dashboards()
    assert set(boards) == {"serve", "drain", "dataplane", "tenancy"}
    exported = {
        "syndeo_serve_requests", "syndeo_serve_shed", "syndeo_serve_p99_ms",
        "syndeo_replica_count", "syndeo_router_queue_depth_bucket",
        "syndeo_moves_committed", "syndeo_moves_aborted",
        "syndeo_relay_fallbacks", "syndeo_head_relayed_bytes",
        "syndeo_worker_drain_pushed_bytes", "syndeo_link_bytes",
        "syndeo_worker_blob_serves", "syndeo_worker_blob_receives",
        "syndeo_broadcast_rounds", "syndeo_tree_edges",
        "syndeo_batched_moves", "syndeo_delta_spill_bytes_saved",
        "syndeo_promotions", "syndeo_tenant_dominant_share",
        "syndeo_tenant_quota_fraction", "syndeo_tenant_sojourn_p99_s",
        "backlog_by_tenant"}
    for uid, board in boards.items():
        assert board["uid"] == f"syndeo-{uid}"
        assert board["schemaVersion"] == 39 and board["panels"]
        for panel in board["panels"]:
            assert panel["targets"], f"panel {panel['title']!r} is empty"
            for target in panel["targets"]:
                # every PromQL expr references at least one name the
                # pipeline actually exports -- a renamed metric breaks
                # this test, not the 2am page
                assert any(name in target["expr"] for name in exported), \
                    f"{uid}/{panel['title']}: {target['expr']!r} " \
                    f"references nothing we export"


# ------------------------------------------- pipeline truthfulness (sim)


def _obs_sim():
    cost = SimCostModel(task_time_s=lambda s: 0.05, jitter=0.0,
                        result_bytes=lambda s: 4096.0,
                        result_location="worker")
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    sim.add_workers(3)
    return sim


def test_sojourn_histograms_track_finished_counters_per_tenant():
    sim = _obs_sim()
    for tenant, n in (("alice", 7), ("bob", 3)):
        sim.run_wave([TaskSpec(fn=None, tenant_id=tenant, max_retries=5)
                      for _ in range(n)])
    export = check_metrics_conformance(sim.store, sim.scheduler,
                                       prom=sim.export_prometheus())
    assert export["syndeo_tenant_sojourn_count"] == {"alice": 7, "bob": 3}
    # 0.05s of service plus a little queueing behind 3 workers: the p99
    # estimate must sit within a bucket or two of that, never at the
    # micro- or kilo-second scales a wall-vs-virtual clock mixup yields
    for tenant in ("alice", "bob"):
        p99 = export["syndeo_tenant_sojourn_p99_s"][tenant]
        assert 0.05 <= p99 <= 0.6, p99
    # dict-valued exposition carries the per-tenant samples
    parsed = parse_prometheus(sim.export_prometheus())
    assert parsed[("syndeo_tenant_sojourn_count", '{key="alice"}')] == 7.0


def test_sojourn_uses_virtual_clock_not_wall_clock():
    """Regression guard: `Task.submitted_at` is wall-monotonic (FIFO
    ordering) but sojourn must be measured on the scheduler's OWN clock
    -- in the sim that is virtual time, so a wave of 0.05s tasks cannot
    report micro- or mega-second sojourns."""
    sim = _obs_sim()
    sim.run_wave([TaskSpec(fn=None, max_retries=5) for _ in range(4)])
    fam = sim.scheduler.metrics.family("syndeo_task_sojourn_seconds")
    [(key, hist)] = list(fam.items())
    assert dict(key) == {"tenant": "default"}
    assert hist.count == 4
    # mean virtual sojourn is a few times the 0.05s service time at most
    assert 0.04 <= hist.sum / hist.count <= 2.0


def test_export_metrics_after_chaos_stays_conformant():
    sim = _obs_sim()
    sim.run_wave([TaskSpec(fn=None, tenant_id="alice", max_retries=5)
                  for _ in range(6)])
    sim.fail_worker_at("w0", 0.0)
    sim.drain_worker_at("w1", 0.0)
    sim.run()
    export = check_metrics_conformance(sim.store, sim.scheduler,
                                       prom=sim.export_prometheus())
    assert export["syndeo_moves_started"] >= 0
    assert export["workers"] == 1
    # dashboards render from the same process without touching state
    assert set(sim.export_dashboards()) == {"serve", "drain", "dataplane",
                                            "tenancy"}


def test_conformance_checker_catches_a_cooked_export():
    """The checker itself must not be a rubber stamp: hand it a snapshot
    with one counter off by one and it must object."""
    sim = _obs_sim()
    sim.run_wave([TaskSpec(fn=None, max_retries=5) for _ in range(3)])
    export = sim.export_metrics()
    export["syndeo_moves_committed"] += 1
    with pytest.raises(AssertionError, match="moves_committed"):
        check_metrics_conformance(sim.store, sim.scheduler, export=export)
    cooked = dict(sim.export_metrics())
    cooked["syndeo_tenant_sojourn_count"] = {"default": 99}
    with pytest.raises(AssertionError, match="sojourn"):
        check_metrics_conformance(sim.store, sim.scheduler, export=cooked)


# --------------------------------- the exit flush (sockets, regression)


def _blob():
    return bytes(200_000)


def _await(cond, timeout=20.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(0.05)
    return False


@pytest.mark.parametrize("flush", [True, False])
def test_drain_flush_keeps_head_aggregates_truthful(tmp_path, flush):
    """Satellite regression: deltas accrued between a worker's last poll
    and its exit (drain pushes, final poll latencies) are flushed in one
    `metric_deltas` frame during the drain handshake. With the flush
    disabled, the head's aggregates diverge from what the worker really
    did -- and the conformance checker MUST catch exactly that."""
    cluster = SyndeoCluster(rendezvous=FileRendezvous(str(tmp_path)))
    server = HeadServer(cluster)
    server.attach()
    truth = {}
    worker = threading.Thread(
        target=run_worker, args=(str(tmp_path), cluster.cluster_id,
                                 "obs-w0"),
        kwargs={"max_idle_s": 60.0, "flush_metrics_on_exit": flush,
                "metrics_truth": truth},
        daemon=True)
    worker.start()
    try:
        assert _await(lambda: any(w.alive for w in
                                  cluster.scheduler.workers.values()))
        t = cluster.submit(_blob)
        assert _await(lambda: cluster.scheduler.graph.tasks[t.id].state
                      == TaskState.FINISHED, timeout=30.0)
        # drain the lone worker: its result blob is pushed to the head's
        # blob server AFTER the final poll delivered the directives --
        # exactly the window only the exit flush can report
        assert server.dispatch({"op": "drain", "worker": "obs-w0"})["ok"]

        def drained():
            cluster.health_check()
            with cluster._lock:
                return "obs-w0" not in cluster.scheduler.workers
        assert _await(drained, timeout=30.0), "drain stuck"
        worker.join(timeout=20.0)
        assert not worker.is_alive()
        assert truth.get("drain_pushed_blobs", 0) >= 1     # scenario armed
        assert truth.get("polls", 0) >= 1

        def conform():
            return check_metrics_conformance(
                cluster.store, cluster.scheduler,
                export=lambda: server.dispatch({"op": "metrics"}),
                prom=lambda: server.dispatch({"op": "metrics_text"}
                                             )["text"],
                worker_truth={"obs-w0": truth})
        if flush:
            export = conform()
            assert export["syndeo_worker_drain_pushed_blobs"] >= 1
        else:
            with pytest.raises(AssertionError, match="lost"):
                conform()
    finally:
        server.shutdown()
        cluster.shutdown()


def test_head_serves_prometheus_and_dashboards_ops(tmp_path):
    cluster = SyndeoCluster(rendezvous=FileRendezvous(str(tmp_path)))
    server = HeadServer(cluster)
    server.attach()
    try:
        reply = server.dispatch({"op": "metrics_text"})
        assert reply["ok"]
        parsed = parse_prometheus(reply["text"])
        assert ("workers", "") in parsed
        boards = server.dispatch({"op": "dashboards"})
        assert boards["ok"] and set(boards["dashboards"]) == {
            "serve", "drain", "dataplane", "tenancy"}
        # the hub recorded the snapshot into its ring-buffer series
        server.dispatch({"op": "metrics"})
        assert len(server.metrics_hub.history("workers")) >= 1
    finally:
        server.shutdown()
        cluster.shutdown()
