"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.
All kernels run in interpret mode on CPU (the kernel body itself executes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- flash attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,T,D,bq,bk", [
    (1, 2, 2, 64, 32, 32, 32),        # MHA
    (2, 4, 2, 128, 64, 64, 32),       # GQA 2:1
    (1, 8, 2, 128, 32, 32, 64),       # GQA 4:1, uneven blocks
    (2, 2, 1, 256, 16, 128, 128),     # long-ish
])
def test_flash_attention_sweep(B, Hq, Hkv, T, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * T + Hq), 3)
    q = _rand(ks[0], (B, Hq, T, D), dtype)
    k = _rand(ks[1], (B, Hkv, T, D), dtype)
    v = _rand(ks[2], (B, Hkv, T, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_window():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (1, 2, 128, 32), jnp.float32)
    k = _rand(ks[1], (1, 2, 128, 32), jnp.float32)
    v = _rand(ks[2], (1, 2, 128, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=32,
                              block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (2, 2, 64, 32), jnp.float32)
    k = _rand(ks[1], (2, 2, 64, 32), jnp.float32)
    v = _rand(ks[2], (2, 2, 64, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([32, 64]), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32]))
def test_flash_attention_property(T, R, D):
    """Property: GQA folding matches explicit KV repetition."""
    Hkv = 2
    ks = jax.random.split(jax.random.PRNGKey(T * R + D), 3)
    q = _rand(ks[0], (1, Hkv * R, T, D), jnp.float32)
    k = _rand(ks[1], (1, Hkv, T, D), jnp.float32)
    v = _rand(ks[2], (1, Hkv, T, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=T // 2,
                              block_k=T // 2)
    krep = jnp.repeat(k, R, axis=1)
    vrep = jnp.repeat(v, R, axis=1)
    want = ref.attention_ref(q, krep, vrep, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------- decode attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 4, 2, 128, 32),
    (1, 8, 8, 256, 64),
    (3, 2, 1, 64, 16),
])
def test_decode_attention_sweep(B, Hq, Hkv, S, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + Hq), 3)
    q = _rand(ks[0], (B, Hq, D), dtype)
    k = _rand(ks[1], (B, Hkv, S, D), dtype)
    v = _rand(ks[2], (B, Hkv, S, D), dtype)
    vl = jnp.arange(1, B + 1) * (S // (B + 1)) + 1
    out = ops.decode_attention(q, k, v, vl, block_k=S // 2)
    want = ref.attention_ref(q[:, :, None], k, v, causal=False,
                             valid_len=vl)[:, :, 0]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_int8_cache():
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(ks[0], (B, Hq, D), jnp.float32)
    kf = _rand(ks[1], (B, Hkv, S, D), jnp.float32)
    vf = _rand(ks[2], (B, Hkv, S, D), jnp.float32)
    # quantize per (head, token)
    ksc = jnp.max(jnp.abs(kf), -1, keepdims=True) / 127.0
    vsc = jnp.max(jnp.abs(vf), -1, keepdims=True) / 127.0
    k8 = jnp.round(kf / ksc).astype(jnp.int8)
    v8 = jnp.round(vf / vsc).astype(jnp.int8)
    vl = jnp.array([64, 128])
    out = ops.decode_attention(q, k8, v8, vl, k_scale=ksc, v_scale=vsc,
                               block_k=64)
    want = ref.attention_ref(q[:, :, None], k8, v8, causal=False,
                             valid_len=vl, kv_scale=ksc, v_scale=vsc)[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # and the dequantized result is close to the fp32 attention
    exact = ref.attention_ref(q[:, :, None], kf, vf, causal=False,
                              valid_len=vl)[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                               atol=0.05, rtol=0.05)


# ------------------------------------------------------------- grouped matmul

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,d,f,bc,bd,bf", [
    (2, 64, 128, 64, 32, 64, 32),
    (4, 32, 64, 128, 32, 32, 64),
    (8, 16, 32, 32, 16, 32, 32),
])
def test_moe_gmm_sweep(E, C, d, f, bc, bd, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(E + C), 2)
    x = _rand(ks[0], (E, C, d), dtype)
    w = _rand(ks[1], (E, d, f), dtype)
    out = ops.moe_gmm(x, w, block_c=bc, block_d=bd, block_f=bf)
    want = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


# ------------------------------------------------------------- SSD scan

@pytest.mark.parametrize("B,H,T,P,G,N,chunk", [
    (1, 2, 64, 16, 1, 8, 16),
    (2, 4, 64, 32, 2, 16, 32),
    (1, 2, 128, 16, 2, 8, 16),
])
def test_ssd_scan_vs_sequential(B, H, T, P, G, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(T + P), 5)
    x = _rand(ks[0], (B, H, T, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (B, H, T), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (H,), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (B, G, T, N), jnp.float32) * 0.5
    Cm = _rand(ks[4], (B, G, T, N), jnp.float32) * 0.5
    y, s_fin = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    want = ref.ssd_chunk_ref(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                             A, Bm.transpose(0, 2, 1, 3),
                             Cm.transpose(0, 2, 1, 3), chunk)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               atol=2e-4, rtol=2e-4)
    assert s_fin.shape == (B, H, P, N)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([16, 32]), st.sampled_from([16, 32, 64]))
def test_ssd_chunk_invariance(chunk, T2):
    """Property: the chunked result is invariant to the chunk size."""
    if T2 % chunk:
        return
    B, H, P, G, N = 1, 2, 16, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(chunk * T2), 5)
    x = _rand(ks[0], (B, H, T2, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (B, H, T2), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (H,), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (B, G, T2, N), jnp.float32) * 0.5
    Cm = _rand(ks[4], (B, G, T2, N), jnp.float32) * 0.5
    y1, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y2, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=T2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------- model-internal chunked forms

def test_mlstm_chunked_matches_sequential():
    from repro.models.xlstm import _mlstm_chunked
    B, T, H, Dh = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = _rand(ks[0], (B, T, H, Dh), jnp.float32)
    k = _rand(ks[1], (B, T, H, Dh), jnp.float32)
    v = _rand(ks[2], (B, T, H, Dh), jnp.float32)
    ig = _rand(ks[3], (B, T, H), jnp.float32)
    fg = _rand(ks[4], (B, T, H), jnp.float32) + 3.0
    lf = jax.nn.log_sigmoid(fg)
    got = _mlstm_chunked(q, k, v, ig, lf, chunk=16)
    want = ref.mlstm_ref(q.transpose(0, 1, 2, 3), k, v, ig, lf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-4, rtol=3e-4)


def test_flash_ref_valid_len_masking():
    """layers.flash_attention_ref per-batch validity (decode masking)."""
    from repro.models.layers import flash_attention_ref
    B, T, H, D = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(ks[0], (B, 1, H, D), jnp.float32)
    k = _rand(ks[1], (B, T, H, D), jnp.float32)
    v = _rand(ks[2], (B, T, H, D), jnp.float32)
    vl = jnp.array([5, 64])
    got = flash_attention_ref(q, k, v, causal=False, valid_len=vl,
                              block_q=1, block_k=16)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=False,
                             valid_len=vl).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
