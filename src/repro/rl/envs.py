"""Pure-JAX environments for the paper's RL rollout benchmark.

Classic control (CartPole, Pendulum, Acrobot) implement the exact Gymnasium
dynamics in jnp. The MuJoCo entries are *surrogates*: correct observation/
action dimensionality and a calibrated per-step compute cost (a dense
contact-solver-shaped workload), because the systems claims of the paper --
throughput scaling vs. worker count -- depend on per-step cost and artifact
size, not on articulated-body dynamics. Calibration: per-step wall cost is
set from the paper's own 28-CPU throughput (Table III), see
benchmarks/paper_tables.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    act_dim: int                  # continuous dims (0 => discrete n_actions)
    n_actions: int = 0
    # per-interaction compute cost on one Xeon E5-2683 core, seconds,
    # derived from Table III: t = 28 / throughput_mean(28 cpus)
    step_cost_s: float = 0.005
    surrogate_dim: int = 0        # internal state size for mujoco surrogates


# paper Table III 28-CPU mean throughputs -> per-step costs
_PAPER_28CPU = {
    "Acrobot": 5656, "Ant": 5106, "Cartpole": 6876, "HalfCheetah": 6343,
    "Hopper": 5505, "Humanoid": 4108, "HumanoidStandup": 3573,
    "InvertedDoublePendulum": 6265, "InvertedPendulum": 5864,
    "Pendulum": 5895, "Pusher": 5939, "Reacher": 6521, "Swimmer": 6168,
    "Walker2d": 5264,
}


def _cost(name: str) -> float:
    return 28.0 / _PAPER_28CPU[name]


ENV_SPECS: Dict[str, EnvSpec] = {
    "Acrobot": EnvSpec("Acrobot", 6, 0, n_actions=3, step_cost_s=_cost("Acrobot")),
    "Cartpole": EnvSpec("Cartpole", 4, 0, n_actions=2, step_cost_s=_cost("Cartpole")),
    "Pendulum": EnvSpec("Pendulum", 3, 1, step_cost_s=_cost("Pendulum")),
    "Ant": EnvSpec("Ant", 27, 8, step_cost_s=_cost("Ant"), surrogate_dim=128),
    "HalfCheetah": EnvSpec("HalfCheetah", 17, 6, step_cost_s=_cost("HalfCheetah"), surrogate_dim=96),
    "Hopper": EnvSpec("Hopper", 11, 3, step_cost_s=_cost("Hopper"), surrogate_dim=64),
    "Humanoid": EnvSpec("Humanoid", 376, 17, step_cost_s=_cost("Humanoid"), surrogate_dim=256),
    "HumanoidStandup": EnvSpec("HumanoidStandup", 376, 17, step_cost_s=_cost("HumanoidStandup"), surrogate_dim=256),
    "InvertedDoublePendulum": EnvSpec("InvertedDoublePendulum", 11, 1, step_cost_s=_cost("InvertedDoublePendulum"), surrogate_dim=32),
    "InvertedPendulum": EnvSpec("InvertedPendulum", 4, 1, step_cost_s=_cost("InvertedPendulum"), surrogate_dim=16),
    "Pusher": EnvSpec("Pusher", 23, 7, step_cost_s=_cost("Pusher"), surrogate_dim=96),
    "Reacher": EnvSpec("Reacher", 11, 2, step_cost_s=_cost("Reacher"), surrogate_dim=32),
    "Swimmer": EnvSpec("Swimmer", 8, 2, step_cost_s=_cost("Swimmer"), surrogate_dim=48),
    "Walker2d": EnvSpec("Walker2d", 17, 6, step_cost_s=_cost("Walker2d"), surrogate_dim=96),
}


# ----------------------------------------------------------------------------
# Exact classic-control dynamics
# ----------------------------------------------------------------------------

def cartpole_step(state, action):
    """Gymnasium CartPole-v1 dynamics. state (4,), action in {0,1}."""
    g, mc, mp, lp, fmag, tau = 9.8, 1.0, 0.1, 0.5, 10.0, 0.02
    x, xd, th, thd = state
    force = jnp.where(action == 1, fmag, -fmag)
    ct, st = jnp.cos(th), jnp.sin(th)
    tmp = (force + mp * lp * thd ** 2 * st) / (mc + mp)
    thacc = (g * st - ct * tmp) / (lp * (4.0 / 3.0 - mp * ct ** 2 / (mc + mp)))
    xacc = tmp - mp * lp * thacc * ct / (mc + mp)
    new = jnp.array([x + tau * xd, xd + tau * xacc,
                     th + tau * thd, thd + tau * thacc])
    done = (jnp.abs(new[0]) > 2.4) | (jnp.abs(new[2]) > 12 * math.pi / 180)
    reward = 1.0
    return new, new, reward, done


def pendulum_step(state, action):
    """Pendulum-v1. state: (th, thd) internal; obs (cos, sin, thd)."""
    g, m, l, dt = 10.0, 1.0, 1.0, 0.05
    th, thd = state[0], state[1]
    u = jnp.clip(action[0], -2.0, 2.0)
    cost = (jnp.mod(th + math.pi, 2 * math.pi) - math.pi) ** 2 \
        + 0.1 * thd ** 2 + 0.001 * u ** 2
    thd_new = thd + (3 * g / (2 * l) * jnp.sin(th) + 3.0 / (m * l ** 2) * u) * dt
    thd_new = jnp.clip(thd_new, -8.0, 8.0)
    th_new = th + thd_new * dt
    new = jnp.array([th_new, thd_new])
    obs = jnp.array([jnp.cos(th_new), jnp.sin(th_new), thd_new])
    return new, obs, -cost, jnp.asarray(False)


def acrobot_step(state, action):
    """Acrobot-v1 (Euler integration variant). state (4,), action {0,1,2}."""
    m1 = m2 = 1.0
    l1 = 1.0
    lc1 = lc2 = 0.5
    I1 = I2 = 1.0
    g, dt = 9.8, 0.2
    th1, th2, d1v, d2v = state
    torque = action.astype(jnp.float32) - 1.0
    d1 = m1 * lc1 ** 2 + m2 * (l1 ** 2 + lc2 ** 2 + 2 * l1 * lc2 * jnp.cos(th2)) + I1 + I2
    d2 = m2 * (lc2 ** 2 + l1 * lc2 * jnp.cos(th2)) + I2
    phi2 = m2 * lc2 * g * jnp.cos(th1 + th2 - math.pi / 2)
    phi1 = (-m2 * l1 * lc2 * d2v ** 2 * jnp.sin(th2)
            - 2 * m2 * l1 * lc2 * d2v * d1v * jnp.sin(th2)
            + (m1 * lc1 + m2 * l1) * g * jnp.cos(th1 - math.pi / 2) + phi2)
    ddth2 = (torque + d2 / d1 * phi1 - m2 * l1 * lc2 * d1v ** 2 * jnp.sin(th2)
             - phi2) / (m2 * lc2 ** 2 + I2 - d2 ** 2 / d1)
    ddth1 = -(d2 * ddth2 + phi1) / d1
    new = jnp.array([th1 + dt * d1v, th2 + dt * d2v,
                     jnp.clip(d1v + dt * ddth1, -4 * math.pi, 4 * math.pi),
                     jnp.clip(d2v + dt * ddth2, -9 * math.pi, 9 * math.pi)])
    obs = jnp.array([jnp.cos(new[0]), jnp.sin(new[0]), jnp.cos(new[1]),
                     jnp.sin(new[1]), new[2], new[3]])
    done = -jnp.cos(new[0]) - jnp.cos(new[1] + new[0]) > 1.0
    return new, obs, -1.0, done


def surrogate_step_fn(spec: EnvSpec):
    """MuJoCo surrogate: a contact-solver-shaped dense workload with the
    right obs/act dims. W is a fixed random internal dynamics matrix."""
    key = jax.random.PRNGKey(hash(spec.name) % (2 ** 31))
    n = spec.surrogate_dim
    W = jax.random.orthogonal(key, n) * 0.99
    Pobs = jax.random.normal(jax.random.fold_in(key, 1), (n, spec.obs_dim)) / math.sqrt(n)
    Pact = jax.random.normal(jax.random.fold_in(key, 2), (spec.act_dim, n)) / math.sqrt(n)

    def step(state, action):
        # a few "solver iterations" of the internal state
        s = state
        for _ in range(3):
            s = jnp.tanh(s @ W + action @ Pact)
        obs = s @ Pobs
        reward = -jnp.mean(jnp.square(obs)) + jnp.mean(action ** 2) * -0.01
        return s, obs, reward, jnp.asarray(False)

    return step


def make_env(name: str):
    """Returns (spec, init_fn(key)->state, step_fn(state, action))."""
    spec = ENV_SPECS[name]
    if name == "Cartpole":
        return spec, lambda k: jax.random.uniform(k, (4,), minval=-0.05, maxval=0.05), cartpole_step
    if name == "Pendulum":
        return spec, lambda k: jax.random.uniform(k, (2,), minval=-1.0, maxval=1.0), pendulum_step
    if name == "Acrobot":
        return spec, lambda k: jax.random.uniform(k, (4,), minval=-0.1, maxval=0.1), acrobot_step
    step = surrogate_step_fn(spec)
    return spec, (lambda k, n=spec.surrogate_dim:
                  jax.random.normal(k, (n,)) * 0.1), step
