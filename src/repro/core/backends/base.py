"""Backend interface: 'write code once and deploy anywhere'.

A backend knows how to (a) render the launch artifacts for its resource
manager and (b) bring the allocation up. Only `LocalBackend` and
`SimBackend` execute in this container; the Slurm/K8s/GCP backends render
deployable artifacts (validated by tests) since no real cluster is attached.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.cluster import ContainerSpec


@dataclass(frozen=True)
class AllocationRequest:
    nodes: int
    cpus_per_node: int = 28
    gpus_per_node: int = 0
    tpu_topology: str = ""           # e.g. "4x4x4" for TPU pods
    walltime: str = "04:00:00"
    partition: str = "normal"
    shared_dir: str = "/shared/syndeo"
    # Slurm: draw nodes from a standing reservation so elastic scale-up is
    # guaranteed capacity instead of hoping the partition has free nodes
    reservation: str = ""


class Backend(abc.ABC):
    name: str = "base"
    supports_elastic: bool = False   # provision/release hooks implemented

    def __init__(self, container: ContainerSpec):
        self.container = container

    @abc.abstractmethod
    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        """filename -> contents for everything this backend needs."""

    # -- elasticity hooks (driven by core/autoscaler.py) ----------------------
    #
    # Render-only backends (Slurm / K8s / GCP-TPU) *render* the scale
    # operation -- the artifacts that grow or shrink the outer allocation --
    # because no real cluster is attached in this container. The in-process
    # local/sim backends actually add/remove workers.

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        """Grow the allocation by `count` workers that join the existing
        rendezvous. Returns filename -> contents of the scale-up artifacts
        (empty for in-process backends, which join workers directly)."""
        raise NotImplementedError(f"{self.name} backend is not elastic")

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        drain_deadline_s: float = 0.0) -> Dict[str, str]:
        """Shrink the allocation by retiring the named workers. The workers
        have already been drained by the scheduler (DRAINING state: no new
        placements, hot objects migrated to survivors); `drain_deadline_s`
        is the grace the rendered artifact gives any process still wrapping
        up on the node before force-releasing it (0 = immediate)."""
        raise NotImplementedError(f"{self.name} backend is not elastic")

    def preempt_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        notice_s: float = 30.0) -> Dict[str, str]:
        """Preemption notice: the resource manager WILL revoke these nodes
        `notice_s` from now (spot reclaim, queued-resource revocation),
        ready or not. Unlike `release_workers` -- where the drain already
        finished -- this *starts* the drain under a hard wall-clock
        deadline: in-flight work and hosted replicas hand off inside the
        notice window, and whatever has not drained when it closes goes
        through the failure path. In-process backends execute the
        deadline; render-only backends return the artifacts that schedule
        the revocation."""
        raise NotImplementedError(f"{self.name} backend is not elastic")
