"""Container artifact generation (paper phase 1: creating the container).

Builds are root-privileged and happen on a development machine; the cluster
only ever *runs* the immutable image as an unprivileged user process. These
renderers emit the Apptainer definition the paper's experiments used
(python + the user's algorithm + Ray-equivalent runtime baked in), plus the
per-backend launch wrappers.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:
    from repro.core.cluster import ContainerSpec


def apptainer_definition(spec: "ContainerSpec") -> str:
    env_lines = "\n".join(f"    export {k}={v}" for k, v in spec.env.items())
    return f"""\
Bootstrap: docker
From: {spec.base.removeprefix('docker://')}

%files
    src /opt/syndeo/src
    pyproject.toml /opt/syndeo/pyproject.toml

%post
    pip install --no-cache-dir /opt/syndeo
    # containers are immutable after build; runtime writes go to the
    # sandbox tmpfs (--writable-tmpfs) and the bound scratch dir only

%environment
    export PYTHONPATH=/opt/syndeo/src
{env_lines}

%runscript
    exec {spec.entrypoint} "$@"
"""


def apptainer_run_command(spec: "ContainerSpec", *, role: str,
                          rendezvous_dir: str, cluster_id: str) -> str:
    binds = " ".join(f"--bind {b}" for b in
                     ([f"{rendezvous_dir}:{rendezvous_dir}"] + list(spec.binds)))
    writable = "--writable-tmpfs" if spec.sandbox_writable else ""
    return (f"apptainer exec {writable} {binds} {spec.image} "
            f"{spec.entrypoint} --role {role} "
            f"--rendezvous {rendezvous_dir} --cluster-id {cluster_id}")
