"""Training loop with production fault tolerance.

Checkpoint/restart, preemption handling, failure injection (tests kill the
loop at arbitrary steps and assert bit-exact resume), optional mesh +
sharding bindings, metrics history. On a real fleet each pod slice runs one
Trainer as a Syndeo job (examples/train_llm.py); the Syndeo head restarts
jobs that lose their slice, and the deterministic data pipeline + atomic
checkpoints make the restart exact.
"""
from __future__ import annotations

import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import TokenPipeline
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer
from repro.train.steps import make_init_state, make_train_step


class Preempted(Exception):
    pass


@dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    n_microbatches: int = 1
    clip_norm: float = 1.0
    base_lr: float = 3e-4
    warmup: int = 10


class Trainer:
    def __init__(self, model: Model, opt: Optimizer, pipeline: TokenPipeline,
                 checkpointer: Checkpointer, cfg: TrainerConfig,
                 lr_fn: Optional[Callable] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.model = model
        self.opt = opt
        self.pipe = pipeline
        self.ckpt = checkpointer
        self.cfg = cfg
        from repro.optim.optimizers import warmup_cosine
        self.lr_fn = lr_fn or warmup_cosine(cfg.base_lr, cfg.warmup,
                                            cfg.num_steps)
        self.failure_hook = failure_hook or (lambda step: None)
        self._preempt = threading.Event()
        self.history: List[Dict[str, float]] = []
        self._step_fn = jax.jit(make_train_step(
            model, opt, self.lr_fn, n_microbatches=cfg.n_microbatches,
            clip_norm=cfg.clip_norm), donate_argnums=(0,))

    def request_preemption(self, *_args):
        """SIGTERM handler on real clusters (Slurm sends it pre-kill)."""
        self._preempt.set()

    def install_signal_handler(self):
        signal.signal(signal.SIGTERM, self.request_preemption)

    # -- state -------------------------------------------------------------------

    def init_or_restore(self, seed: int = 0) -> Dict[str, Any]:
        init = make_init_state(self.model, self.opt)
        latest = self.ckpt.latest_step()
        if latest is None:
            return init(jax.random.PRNGKey(seed))
        like = jax.eval_shape(init, jax.random.PRNGKey(seed))
        state = self.ckpt.restore(like)
        return jax.tree.map(jnp.asarray, state)

    # -- loop --------------------------------------------------------------------

    def run(self, state: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        state = state if state is not None else self.init_or_restore()
        start = int(state["step"])
        t0 = time.time()
        for step in range(start, self.cfg.num_steps):
            if self._preempt.is_set():
                self.ckpt.save(step, state, blocking=True)
                raise Preempted(f"preempted at step {step} (checkpoint saved)")
            self.failure_hook(step)   # tests inject crashes here
            batch = jax.tree.map(jnp.asarray, self.pipe.batch_at(step))
            state, metrics = self._step_fn(state, batch)
            if step % self.cfg.log_every == 0 or step == self.cfg.num_steps - 1:
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = step
                rec["wall_s"] = time.time() - t0
                self.history.append(rec)
            if (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        self.ckpt.save(self.cfg.num_steps, state, blocking=True)
        return state
