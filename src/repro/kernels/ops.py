"""jit'd public wrappers over the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as traced jnp ops); on a real TPU set interpret=False (or export
REPRO_PALLAS_COMPILE=1). The model code's jnp reference path remains the
numerics oracle (kernels/ref.py) -- tests assert allclose across shape and
dtype sweeps.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_gmm import moe_gmm as _gmm
from repro.kernels.ssm_scan import ssd_scan as _ssd

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=256, block_k=256):
    """q (B,Hq,T,D); k/v (B,Hkv,T,D)."""
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, valid_len, k_scale=None, v_scale=None,
                     *, block_k=512):
    """q (B,Hq,D); k/v cache (B,Hkv,S,D) [+int8 scales]; valid_len (B,)."""
    return _decode(q, k, v, valid_len, k_scale=k_scale, v_scale=v_scale,
                   block_k=block_k, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("block_c", "block_d", "block_f"))
def moe_gmm(x, w, *, block_c=128, block_d=512, block_f=256):
    """Grouped expert matmul: (E,C,d) @ (E,d,f) -> (E,C,f)."""
    return _gmm(x, w, block_c=block_c, block_d=block_d, block_f=block_f,
                interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk=256):
    """Mamba2 SSD: x (B,H,T,P), dt (B,H,T), A (H,), Bm/Cm (B,G,T,N)."""
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=_INTERPRET)


__all__ = ["flash_attention", "decode_attention", "moe_gmm", "ssd_scan", "ref"]
