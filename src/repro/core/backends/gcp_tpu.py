"""Cloud-TPU backend: queued-resources allocation of TPU pod slices and a
per-host launch of the Syndeo worker + jax.distributed bootstrap.

This is the TPU adaptation of the paper's cloud path: the *outer* scheduler
is Cloud TPU's queued-resource manager (or GKE), the *inner* scheduler is
the Syndeo runtime, and within a training job XLA owns the chips (three
nested schedulers -- see DESIGN.md)."""
from __future__ import annotations

from typing import Dict

from repro.core.backends.base import AllocationRequest, Backend


class GcpTpuBackend(Backend):
    name = "gcp_tpu"

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        topo = req.tpu_topology or "16x16"
        create = f"""\
#!/bin/bash
set -euo pipefail
# outer scheduler: allocate the pod slices (gang allocation)
for POD in $(seq 0 {max(req.nodes - 1, 0)}); do
  gcloud compute tpus queued-resources create syndeo-{cluster_id}-$POD \\
    --node-id syndeo-{cluster_id}-$POD \\
    --accelerator-type v5litepod-256 \\
    --runtime-version v2-alpha-tpuv5-lite \\
    --zone us-central1-a &
done
wait
"""
        launch = f"""\
#!/bin/bash
set -euo pipefail
# middle scheduler: start the Syndeo head on pod 0 host 0, workers on all
# hosts; rendezvous via the GCS bucket (the cloud 'shared location').
RDV=gs://syndeo-rdv/{cluster_id}
for POD in $(seq 0 {max(req.nodes - 1, 0)}); do
  gcloud compute tpus tpu-vm ssh syndeo-{cluster_id}-$POD --worker=all \\
    --zone us-central1-a --command "
      docker run --privileged=false --net=host --user 1000:1000 \\
        {self.container.image.replace('.sif', ':latest')} \\
        python -m repro.core.worker \\
          --role \\$( [ $POD -eq 0 ] && echo head || echo worker ) \\
          --rendezvous $RDV --cluster-id {cluster_id} \\
          --jax-coordinator \\${{POD}}:8476 --mesh {topo}
    " &
done
wait
"""
        return {f"allocate_{cluster_id}.sh": create,
                f"launch_{cluster_id}.sh": launch}
