"""Kubernetes backend (paper §III-E: cloud deployment).

Renders a head Service + head Pod + worker Deployment running the same
Apptainer image (via the sif->OCI bridge or directly as an OCI image). The
rendezvous is a ConfigMap-backed shared mount -- same write-then-poll
protocol as the Slurm shared filesystem."""
from __future__ import annotations

from typing import Dict

from repro.core.backends.base import AllocationRequest, Backend


class KubernetesBackend(Backend):
    name = "kubernetes"

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        image = self.container.image.replace(".sif", ":latest")
        manifest = f"""\
apiVersion: v1
kind: Service
metadata:
  name: syndeo-head-{cluster_id}
spec:
  selector:
    app: syndeo-{cluster_id}
    role: head
  ports:
  - port: 6379
---
apiVersion: v1
kind: Pod
metadata:
  name: syndeo-head-{cluster_id}
  labels: {{app: syndeo-{cluster_id}, role: head}}
spec:
  securityContext:
    runAsNonRoot: true            # the Apptainer principle, K8s-enforced
    runAsUser: 1000
  containers:
  - name: head
    image: {image}
    command: ["{self.container.entrypoint.split()[0]}"]
    args: ["-m", "repro.core.worker", "--role", "head",
           "--rendezvous", "{req.shared_dir}", "--cluster-id", "{cluster_id}"]
    resources:
      requests: {{cpu: "{req.cpus_per_node}"}}
    volumeMounts:
    - name: rdv
      mountPath: {req.shared_dir}
  volumes:
  - name: rdv
    persistentVolumeClaim: {{claimName: syndeo-shared}}
---
apiVersion: apps/v1
kind: Deployment
metadata:
  name: syndeo-workers-{cluster_id}
spec:
  replicas: {req.nodes - 1}
  selector:
    matchLabels: {{app: syndeo-{cluster_id}, role: worker}}
  template:
    metadata:
      labels: {{app: syndeo-{cluster_id}, role: worker}}
    spec:
      securityContext:
        runAsNonRoot: true
        runAsUser: 1000
      containers:
      - name: worker
        image: {image}
        command: ["{self.container.entrypoint.split()[0]}"]
        args: ["-m", "repro.core.worker", "--role", "worker",
               "--rendezvous", "{req.shared_dir}", "--cluster-id", "{cluster_id}"]
        resources:
          requests: {{cpu: "{req.cpus_per_node}"}}
        volumeMounts:
        - name: rdv
          mountPath: {req.shared_dir}
      volumes:
      - name: rdv
        persistentVolumeClaim: {{claimName: syndeo-shared}}
"""
        return {f"syndeo_{cluster_id}.yaml": manifest}
