"""known-good: the same work with I/O hoisted out of the lock."""
import threading
import time


class Cache:
    def __init__(self, sock):
        self._lock = threading.Lock()
        self.sock = sock
        self.items = {}

    def refresh(self):
        data = self.sock.recv(4096)           # I/O outside the lock
        with self._lock:
            self.items["latest"] = data

    def tick(self):
        self._poll()                          # sleep outside the lock
        with self._lock:
            self.items.pop("stale", None)

    def _poll(self):
        time.sleep(0.5)
