"""Continuous-batching serving engine.

Fixed B decode slots over a static-shaped KV cache (TPU-friendly: one
compiled decode step, no re-compilation as requests come and go):
  * new requests are prefilled one-at-a-time (padded to the prefill bucket)
    and their cache scattered into a free slot,
  * every engine tick decodes all active slots in one batched step,
  * finished slots (EOS or max_len) are freed and refilled from the queue.

On a pod this engine is one long-lived Syndeo actor per model replica; the
Syndeo scheduler routes request batches to replicas (placement groups pin
them to pod slices).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclass
class Request:
    id: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: int = -1          # -1: never
    output: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int, max_len: int):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.positions = jnp.zeros((batch_slots,), jnp.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: "collections.deque[Request]" = collections.deque()
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill_one = jax.jit(self._prefill_impl)
        self._completed: List[Request] = []
        self.stats = {"ticks": 0, "prefills": 0, "decoded_tokens": 0,
                      "completed": 0}

    def _prefill_impl(self, params, tokens):
        return self.model.prefill(params, {"tokens": tokens})

    # -- request management ------------------------------------------------------

    def add_request(self, req: Request):
        self.queue.append(req)

    @property
    def free_slots(self) -> int:
        """Decode slots with no active request (prefill capacity), net of
        queued requests that will claim one at the next tick."""
        empty = sum(1 for r in self.slot_req if r is None)
        return max(0, empty - len(self.queue))

    @property
    def queue_len(self) -> int:
        return len(self.queue)

    @property
    def outstanding_tokens(self) -> int:
        """Tokens still owed to admitted requests -- the router's
        least-outstanding-tokens tiebreak reads this, so it counts queued
        requests (full budget) plus active slots (budget minus emitted)."""
        owed = sum(r.max_new_tokens for r in self.queue)
        owed += sum(r.max_new_tokens - len(r.output)
                    for r in self.slot_req if r is not None)
        return owed

    def _fill_free_slots(self):
        for slot in range(self.B):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pcache = self._prefill_one(self.params, prompt)
            next_tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(next_tok)
            self._scatter_cache(pcache, slot, len(req.prompt))
            self.positions = self.positions.at[slot].set(len(req.prompt))
            self.tokens = self.tokens.at[slot, 0].set(next_tok)
            self.slot_req[slot] = req
            self.stats["prefills"] += 1

    def _scatter_cache(self, pcache, slot: int, plen: int):
        """Copy a 1-seq prefill cache into batch slot `slot`."""
        def per_leaf(big, small):
            if big.ndim < 2 or big.shape[1] != self.B:
                return big
            pad_width = [(0, 0)] * small.ndim
            pad_width[2] = (0, big.shape[2] - small.shape[2])
            small_p = jnp.pad(small, pad_width)
            return big.at[:, slot].set(small_p[:, 0].astype(big.dtype))
        self.cache = jax.tree.map(per_leaf, self.cache, pcache)

    # -- the decode tick -----------------------------------------------------------

    def tick(self) -> int:
        """One engine iteration; returns number of active slots decoded."""
        self._fill_free_slots()
        active = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not active:
            return 0
        batch = {"tokens": self.tokens, "positions": self.positions}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        next_tokens = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        self.positions = self.positions + 1
        self.stats["ticks"] += 1
        for s in active:
            req = self.slot_req[s]
            tok = int(next_tokens[s])
            req.output.append(tok)
            self.stats["decoded_tokens"] += 1
            limit = len(req.output) >= req.max_new_tokens
            if tok == req.eos_id or limit or int(self.positions[s]) >= self.max_len - 1:
                req.done = True
                self.slot_req[s] = None
                self._completed.append(req)
                self.stats["completed"] += 1
        self.tokens = jnp.asarray(next_tokens, jnp.int32)[:, None]
        return len(active)

    def pop_completed(self) -> List[Request]:
        """Requests finished since the last pop (the router's per-tick
        harvest)."""
        out, self._completed = self._completed, []
        return out

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.tick()
        return self.pop_completed()


class StubEngine:
    """Model-free reference engine with ServeEngine's exact admission
    semantics (B slots, queue, per-tick completion), for the router, the
    sim cost model, and CI hosts without an accelerator.

    Deterministic: a request's output is a pure function of its prompt
    (`stub_output`), so a routed K-replica execution must be
    token-identical to one local engine -- the completion-equivalence
    property in tests/test_serve_plane.py. Each tick decodes one token
    per active slot, mirroring the batched decode step."""

    def __init__(self, batch_slots: int, max_len: int = 1 << 30):
        self.B = batch_slots
        self.max_len = max_len
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: "collections.deque[Request]" = collections.deque()
        self._completed: List[Request] = []
        self.stats = {"ticks": 0, "prefills": 0, "decoded_tokens": 0,
                      "completed": 0}

    @staticmethod
    def stub_output(prompt: List[int], n: int) -> List[int]:
        """The deterministic "model": token i is a rolling digest of the
        prompt -- replica-independent, so routing never changes outputs."""
        acc = 1469598103  # FNV-ish seed
        for t in prompt:
            acc = (acc * 16777619 + int(t)) & 0x7FFFFFFF
        out = []
        for _ in range(n):
            acc = (acc * 16777619 + 13) & 0x7FFFFFFF
            out.append(acc % 50_000)
        return out

    def add_request(self, req: Request):
        self.queue.append(req)

    @property
    def free_slots(self) -> int:
        empty = sum(1 for r in self.slot_req if r is None)
        return max(0, empty - len(self.queue))

    @property
    def queue_len(self) -> int:
        return len(self.queue)

    @property
    def outstanding_tokens(self) -> int:
        owed = sum(r.max_new_tokens for r in self.queue)
        owed += sum(r.max_new_tokens - len(r.output)
                    for r in self.slot_req if r is not None)
        return owed

    def _fill_free_slots(self):
        for slot in range(self.B):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # prefill emits the first token, exactly like ServeEngine
            req.output.append(
                self.stub_output(req.prompt, len(req.output) + 1)[-1])
            self.slot_req[slot] = req
            self.stats["prefills"] += 1

    def tick(self) -> int:
        self._fill_free_slots()
        active = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not active:
            return 0
        self.stats["ticks"] += 1
        for s in active:
            req = self.slot_req[s]
            tok = self.stub_output(req.prompt, len(req.output) + 1)[-1]
            req.output.append(tok)
            self.stats["decoded_tokens"] += 1
            if (tok == req.eos_id
                    or len(req.output) >= req.max_new_tokens):
                req.done = True
                self.slot_req[s] = None
                self._completed.append(req)
                self.stats["completed"] += 1
        return len(active)

    def pop_completed(self) -> List[Request]:
        out, self._completed = self._completed, []
        return out

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.tick()
        return self.pop_completed()
