"""Elastic-autoscaling + scheduler fast-path benchmark.

Two measurements, both on the REAL scheduler code (the simulation backend
drives the same Scheduler/ObjectStore as the threaded backend):

1. *Placement throughput*: per-decision scheduling rate of the indexed
   placement fast-path (resource-keyed lazy heaps, ~O(log n)) vs the seed's
   linear scan (O(n)) at 10..1000 workers. The paper's head-serialization
   bottleneck makes every microsecond of head-side work count; this is the
   decision loop itself.

2. *Elasticity scenarios*: bursty, steady, and ramp workloads against an
   autoscaled SimCluster, reporting time-to-scale, scale-up/-down events,
   mean utilization, and makespan.

3. *Drain vs drop*: retire object-holding workers via the graceful drain
   pipeline (hot objects migrate to survivors) vs the drop path (objects
   lost, lineage re-executes producers), reporting re-executed producer
   tasks and consumer-wave makespan. Drain must re-execute ZERO producers.

Run:  PYTHONPATH=src python benchmarks/autoscale_bench.py [--quick]
      PYTHONPATH=src python benchmarks/autoscale_bench.py --drain-smoke
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

from repro.core import (AutoscalerConfig, Scheduler, SchedulerConfig,
                        SimCluster, SimCostModel, TaskSpec, WorkerInfo)
from repro.core.object_store import GlobalObjectStore
from repro.core.task_graph import Task, TaskState

# ------------------------------------------------------------------ placement


def placement_throughput(n_workers: int, n_tasks: int, mode: str) -> float:
    """Decisions/second for one full scheduling pass placing n_tasks."""
    store = GlobalObjectStore()
    sched = Scheduler(store, lambda t, w: None,
                      config=SchedulerConfig(placement_mode=mode,
                                             enable_speculation=False))
    cpus = max(1.0, float(-(-n_tasks // n_workers)))   # enough capacity
    for i in range(n_workers):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": cpus}))
    # build the ready set directly so timing covers exactly one schedule()
    for i in range(n_tasks):
        sched.graph.add(Task(spec=TaskSpec(fn=None, name=f"t{i}")))
    t0 = time.perf_counter()
    sched.schedule()
    elapsed = time.perf_counter() - t0
    placed = sum(1 for t in sched.graph.tasks.values()
                 if t.state == TaskState.RUNNING)
    assert placed == n_tasks, (placed, n_tasks)
    return n_tasks / elapsed


def bench_placement(worker_counts: List[int], n_tasks: int
                    ) -> List[Tuple[int, float, float]]:
    rows = []
    for n in worker_counts:
        linear = placement_throughput(n, n_tasks, "linear")
        indexed = placement_throughput(n, n_tasks, "indexed")
        rows.append((n, linear, indexed))
    return rows


# ------------------------------------------------------------------ scenarios


def _mk_sim(n0: int, task_s: float, auto_cfg: AutoscalerConfig,
            provision_delay_s: float, seed: int = 0) -> SimCluster:
    cost = SimCostModel(task_time_s=lambda s: task_s,
                        result_bytes=lambda s: 1000.0, jitter=0.05)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9), seed=seed)
    sim.add_workers(n0)
    sim.attach_autoscaler(auto_cfg, provision_delay_s=provision_delay_s)
    return sim


def _instrument(sim: SimCluster) -> List[Tuple[float, int, int]]:
    """Sample (t, busy, alive) at every autoscaler tick."""
    samples: List[Tuple[float, int, int]] = []
    orig = sim.autoscaler.tick

    def tick(now=None):
        workers = [w for w in sim.scheduler.workers.values() if w.alive]
        samples.append((sim.now, sum(1 for w in workers if w.running),
                        len(workers)))
        return orig(now)

    sim.autoscaler.tick = tick
    return samples


def _summarize(name: str, sim: SimCluster,
               samples: List[Tuple[float, int, int]],
               demand_at: float) -> Dict[str, float]:
    ups = [e for e in sim.autoscaler.events if e.action == "scale_up"]
    downs = [e for e in sim.autoscaler.events if e.action == "scale_down"]
    peak = max((s[2] for s in samples), default=0)
    t_peak = next((s[0] for s in samples if s[2] == peak), 0.0)
    busy_sum = sum(s[1] for s in samples)
    alive_sum = sum(s[2] for s in samples) or 1
    done = sum(1 for t in sim.scheduler.graph.tasks.values()
               if t.state == TaskState.FINISHED)
    return {"name": name, "tasks_done": done,
            "scale_ups": len(ups), "scale_downs": len(downs),
            "workers_added": sum(e.count for e in ups),
            "workers_released": sum(e.count for e in downs),
            "peak_workers": peak, "final_workers": len(sim.scheduler.workers),
            "time_to_scale_s": max(0.0, t_peak - demand_at),
            "mean_utilization": busy_sum / alive_sum,
            "makespan_s": sim.now}


def scenario_bursty(max_workers: int, burst: int) -> Dict[str, float]:
    """Idle baseline, one large burst, then drain: tests time-to-scale and
    idle scale-down."""
    cfg = AutoscalerConfig(min_workers=2, max_workers=max_workers,
                           queue_depth_per_worker=1.0,
                           scale_up_cooldown_s=0.2, max_scale_up_step=256,
                           idle_timeout_s=2.0, scale_down_cooldown_s=1.0,
                           max_scale_down_step=256)
    sim = _mk_sim(2, task_s=1.0, auto_cfg=cfg, provision_delay_s=0.5)
    samples = _instrument(sim)
    arrivals = [(1.0, TaskSpec(fn=None, group="burst")) for _ in range(burst)]
    sim.run_scenario(arrivals, tick_every=0.1, drain_s=6.0)
    return _summarize("bursty", sim, samples, demand_at=1.0)


def scenario_steady(max_workers: int, n_tasks: int) -> Dict[str, float]:
    """Constant arrival rate above the initial capacity: the pool should
    grow to a steady size and hold a sane utilization."""
    cfg = AutoscalerConfig(min_workers=4, max_workers=max_workers,
                           queue_depth_per_worker=2.0,
                           scale_up_cooldown_s=0.3, max_scale_up_step=16,
                           idle_timeout_s=3.0, scale_down_cooldown_s=2.0)
    sim = _mk_sim(4, task_s=0.5, auto_cfg=cfg, provision_delay_s=0.5)
    samples = _instrument(sim)
    arrivals = [(0.02 * i, TaskSpec(fn=None, group="steady"))
                for i in range(n_tasks)]
    sim.run_scenario(arrivals, tick_every=0.1, drain_s=8.0)
    return _summarize("steady", sim, samples, demand_at=0.0)


def scenario_ramp(max_workers: int, n_tasks: int) -> Dict[str, float]:
    """Linearly increasing arrival rate: worker count should track demand."""
    cfg = AutoscalerConfig(min_workers=2, max_workers=max_workers,
                           queue_depth_per_worker=2.0,
                           scale_up_cooldown_s=0.3, max_scale_up_step=32,
                           idle_timeout_s=3.0, scale_down_cooldown_s=2.0)
    sim = _mk_sim(2, task_s=0.5, auto_cfg=cfg, provision_delay_s=0.5)
    samples = _instrument(sim)
    # arrival times t_i = sqrt(i) * c  ->  rate grows linearly with time
    horizon = 10.0
    arrivals = [(horizon * (i / n_tasks) ** 0.5,
                 TaskSpec(fn=None, group="ramp")) for i in range(n_tasks)]
    sim.run_scenario(arrivals, tick_every=0.1, drain_s=8.0)
    return _summarize("ramp", sim, samples, demand_at=0.0)


# ------------------------------------------------------------- drain vs drop


def _run_ids_to_completion(sim: SimCluster, ids: List[str],
                           horizon_s: float = 600.0):
    terminal = {TaskState.FINISHED, TaskState.FAILED, TaskState.CANCELLED}
    deadline = sim.now + horizon_s

    def monitor():
        if sim.now > deadline:
            raise RuntimeError("drain benchmark did not converge")
        sim.scheduler.check_stragglers()
        sim.scheduler.check_drains(sim.now)
        if {sim.scheduler.graph.tasks[i].state for i in ids} <= terminal:
            return
        sim._post(0.05, monitor)

    sim._post(0.05, monitor)
    sim.run()


def scenario_drain_vs_drop(mode: str, n_workers: int = 8,
                           n_objects: int = 32, retire: int = 3,
                           task_s: float = 0.08) -> Dict[str, float]:
    """Produce objects on workers, retire `retire` holders via `mode`
    ("drain" | "drop"), then run a consumer wave that reads every object."""
    cost = SimCostModel(task_time_s=lambda s: task_s,
                        result_bytes=lambda s: 32_768.0, jitter=0.0,
                        result_location="worker")
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9), seed=3)
    sim.add_workers(n_workers)
    sim.run_wave([TaskSpec(fn=None, group="produce", max_retries=10)
                  for _ in range(n_objects)])
    refs = [t.output for t in sim.scheduler.graph.tasks.values()
            if t.output is not None]
    victims = sorted({next(iter(sim.store.locations(r)))
                      for r in refs})[:retire]
    if mode == "drain":
        for wid in victims:
            sim.drain_worker_at(wid, sim.now)
        sim.run()                      # idle drains: migrations complete
    else:
        for wid in victims:
            sim.scheduler.retire_worker(wid)   # PR-1 drop path
    reexec_before = sim.scheduler.stats["reconstructed"]
    t0 = sim.now
    ids = [sim.submit(TaskSpec(fn=None, group="consume", max_retries=10),
                      deps=[r]).id for r in refs]
    _run_ids_to_completion(sim, ids)
    failed = sum(1 for i in ids
                 if sim.scheduler.graph.tasks[i].state != TaskState.FINISHED)
    return {"name": f"retire-{mode}",
            "reexecuted_producers":
                sim.scheduler.stats["reconstructed"] - reexec_before,
            "migrated_objects": sim.scheduler.stats["migrated_objects"],
            "consumer_failures": failed,
            "wave_makespan_s": sim.now - t0}


def bench_drain_vs_drop(**kw) -> Tuple[Dict[str, float], Dict[str, float]]:
    return scenario_drain_vs_drop("drain", **kw), \
        scenario_drain_vs_drop("drop", **kw)


# ------------------------------------------------------------------ reporting


def report_drain_vs_drop(quick: bool) -> bool:
    kw = dict(n_workers=6, n_objects=16, retire=2) if quick \
        else dict(n_workers=8, n_objects=48, retire=3)
    drain, drop = bench_drain_vs_drop(**kw)
    cols = ["name", "reexecuted_producers", "migrated_objects",
            "consumer_failures", "wave_makespan_s"]
    print("\n=== drain vs drop retirement (virtual time) ===")
    print("".join(f"{c:>22s}" for c in cols))
    for row in (drain, drop):
        print("".join(f"{row[c]:>22.3f}" if isinstance(row[c], float)
                      else f"{row[c]:>22}" for c in cols))
    ok = True
    if drain["reexecuted_producers"] != 0:
        print("\nFAIL: drain re-executed producers for hot objects")
        ok = False
    if drop["reexecuted_producers"] == 0:
        print("\nFAIL: drop baseline did not exercise lineage recompute")
        ok = False
    if drain["consumer_failures"] or drop["consumer_failures"]:
        print("\nFAIL: consumer tasks failed during retirement")
        ok = False
    if drain["wave_makespan_s"] > drop["wave_makespan_s"]:
        print("\nFAIL: draining was slower than recompute")
        ok = False
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes for CI smoke")
    ap.add_argument("--drain-smoke", action="store_true",
                    help="run only the drain-vs-drop comparison")
    args = ap.parse_args()

    if args.drain_smoke:
        ok = report_drain_vs_drop(quick=True)
        print("\nPASS" if ok else "\nFAIL")
        return 0 if ok else 1

    if args.quick:
        worker_counts, n_tasks = [10, 100, 500], 1000
        shapes = [scenario_bursty(64, 200), scenario_steady(32, 300),
                  scenario_ramp(64, 300)]
    else:
        worker_counts, n_tasks = [10, 100, 500, 1000], 2000
        shapes = [scenario_bursty(1000, 2000), scenario_steady(64, 1000),
                  scenario_ramp(256, 1500)]

    print("=== placement throughput (decisions/s, one schedule() pass) ===")
    print(f"{'workers':>8s}{'linear':>12s}{'indexed':>12s}{'speedup':>9s}")
    ratio_at_500 = None
    for n, lin, idx in bench_placement(worker_counts, n_tasks):
        ratio = idx / lin
        if n >= 500 and ratio_at_500 is None:
            ratio_at_500 = ratio
        print(f"{n:>8d}{lin:>12.0f}{idx:>12.0f}{ratio:>8.1f}x")

    print("\n=== elasticity scenarios (virtual time) ===")
    cols = ["name", "tasks_done", "scale_ups", "scale_downs",
            "workers_added", "workers_released", "peak_workers",
            "final_workers", "time_to_scale_s", "mean_utilization",
            "makespan_s"]
    print("".join(f"{c:>17s}" for c in cols))
    for row in shapes:
        print("".join(
            f"{row[c]:>17.2f}" if isinstance(row[c], float)
            else f"{row[c]:>17}" for c in cols))

    ok = report_drain_vs_drop(quick=args.quick)
    if ratio_at_500 is not None and ratio_at_500 < 5.0:
        print(f"\nFAIL: indexed speedup at 500+ workers is "
              f"{ratio_at_500:.1f}x (< 5x)")
        ok = False
    for row in shapes:
        if row["scale_ups"] == 0 or row["scale_downs"] == 0:
            print(f"\nFAIL: scenario {row['name']} did not exercise both "
                  f"scale directions")
            ok = False
    print("\nPASS" if ok else "\nFAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
