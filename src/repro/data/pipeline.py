"""Deterministic, resumable, sharded token pipeline.

Production properties:
  * each data-parallel host reads only its shard (shard_id/num_shards),
  * the stream is a pure function of (seed, step) -> batch, so restarts
    resume exactly (the trainer checkpoints just the step counter),
  * double-buffered prefetch on a background thread hides host latency,
  * sources: synthetic LM stream (default; zipf-ish token draw) or packed
    token files (one uint32 memmap per shard directory).
"""
from __future__ import annotations

import os
import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    shard_id: int = 0
    num_shards: int = 1
    seed: int = 0
    source: str = "synthetic"         # "synthetic" | "files"
    path: Optional[str] = None
    prefetch: int = 2


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards
        self._mm = None
        if cfg.source == "files":
            path = os.path.join(cfg.path, f"shard_{cfg.shard_id:05d}.bin")
            self._mm = np.memmap(path, dtype=np.uint32, mode="r")

    # -- deterministic batch function -------------------------------------------

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if self._mm is not None:
            n = self.local_batch * (cfg.seq_len + 1)
            start = (step * n) % max(len(self._mm) - n, 1)
            flat = np.asarray(self._mm[start:start + n], dtype=np.int32)
            toks = flat.reshape(self.local_batch, cfg.seq_len + 1)
        else:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, cfg.shard_id, step]))
            # zipf-ish marginal: realistic softmax-xent magnitudes
            z = rng.zipf(1.3, size=(self.local_batch, cfg.seq_len + 1))
            toks = np.minimum(z, cfg.vocab_size - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    # -- prefetching iterator ------------------------------------------------------

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iterate(start_step=0)

    def iterate(self, start_step: int) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def produce():
            s = start_step
            while not stop.is_set():
                q.put(self.batch_at(s))
                s += 1

        t = threading.Thread(target=produce, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass
