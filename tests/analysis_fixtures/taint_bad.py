"""known-bad: raw socket bytes reach a store mutation (SYN-A001)."""
import json


class BlobIngest:
    def __init__(self, store):
        self.store = store

    def handle(self, sock):
        header = json.loads(sock.recv(4096).decode())
        self.store.put_blob(header["object"], header["data"])
