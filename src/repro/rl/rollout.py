"""RL rollout actors: the paper's benchmark workload on the Syndeo runtime.

Each actor hosts one environment + a fully-connected policy network and
collects state-action interactions (paper §IV). `rollout_task` is the unit
of work the Syndeo scheduler dispatches; `run_benchmark_local` drives real
rollouts through the threaded local cluster, and benchmarks/paper_tables.py
drives the same scheduler at paper scale under virtual time.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.envs import ENV_SPECS, make_env


def init_policy(key, obs_dim: int, act_out: int, hidden: int = 64):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (obs_dim, hidden)) / np.sqrt(obs_dim),
        "w2": jax.random.normal(k2, (hidden, hidden)) / np.sqrt(hidden),
        "w3": jax.random.normal(k3, (hidden, act_out)) / np.sqrt(hidden),
    }


def policy_apply(params, obs):
    h = jnp.tanh(obs @ params["w1"])
    h = jnp.tanh(h @ params["w2"])
    return h @ params["w3"]


def make_rollout_fn(env_name: str, n_steps: int):
    """Pure-JAX rollout of n_steps interactions (scan), jitted once."""
    spec, init_fn, step_fn = make_env(env_name)
    act_out = spec.n_actions if spec.n_actions else spec.act_dim

    def rollout(key):
        kp, ke = jax.random.split(key)
        params = init_policy(kp, spec.obs_dim, act_out)
        state = init_fn(ke)
        obs0 = jnp.zeros((spec.obs_dim,))

        def step(carry, _):
            state, obs = carry
            logits = policy_apply(params, obs)
            if spec.n_actions:
                action = jnp.argmax(logits)
            else:
                action = jnp.tanh(logits)
            new_state, new_obs, reward, done = step_fn(state, action)
            new_obs = jnp.resize(new_obs, (spec.obs_dim,))
            return (new_state, new_obs), (new_obs, reward)

        (_, _), (obs_traj, rewards) = jax.lax.scan(
            step, (state, obs0), None, length=n_steps)
        return obs_traj, rewards

    return jax.jit(rollout), spec


def rollout_task(env_name: str, n_steps: int, seed: int) -> Dict:
    """The Syndeo task: collect n_steps interactions, return the artifact
    (observation trajectory -- its SIZE is what stresses the object store,
    exactly the paper's Humanoid effect)."""
    fn, spec = make_rollout_fn(env_name, n_steps)
    t0 = time.perf_counter()
    obs_traj, rewards = fn(jax.random.PRNGKey(seed))
    obs_traj = np.asarray(obs_traj)
    return {
        "env": env_name,
        "interactions": int(n_steps),
        "wall_s": time.perf_counter() - t0,
        "obs": obs_traj,                     # (n_steps, obs_dim) artifact
        "reward_sum": float(jnp.sum(rewards)),
    }


def run_benchmark_local(cluster, env_name: str, n_workers: int,
                        steps_per_worker: int = 1000) -> Tuple[float, Dict]:
    """Real (threaded) run on a SyndeoCluster: returns (throughput, stats)."""
    t0 = time.perf_counter()
    tasks = [cluster.submit(rollout_task, env_name, steps_per_worker, i,
                            group=f"rollout-{env_name}")
             for i in range(n_workers)]
    results = cluster.wait_all(tasks, timeout=600.0)
    wall = time.perf_counter() - t0
    total = sum(r["interactions"] for r in results)
    return total / wall, {"wall_s": wall, "n_tasks": len(results)}
