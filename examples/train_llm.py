"""End-to-end training driver: data pipeline -> sharded train loop ->
async checkpoints -> restart-safe, dispatched as a Syndeo job.

    PYTHONPATH=src python examples/train_llm.py --preset demo
    PYTHONPATH=src python examples/train_llm.py --preset 100m --steps 300

demo: a ~1M-param llama-family model, 40 steps (seconds on CPU).
100m: a ~100M-param model, a few hundred steps (the deliverable (b) driver;
      give it minutes on CPU or run it on a real slice via --arch/--mesh).
Any --arch <id> from the zoo works (full configs are for TPU pods; on CPU
stick to the smoke/demo presets).
"""
import argparse
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ModelConfig
from repro.core import SyndeoCluster
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    "demo": dict(d_model=128, n_layers=4, n_heads=4, n_kv_heads=2, d_ff=512,
                 vocab=2048, seq=128, batch=8, steps=40),
    "100m": dict(d_model=640, n_layers=12, n_heads=10, n_kv_heads=5,
                 d_ff=2560, vocab=32000, seq=512, batch=8, steps=300),
}


def make_cfg(preset) -> ModelConfig:
    p = PRESETS[preset]
    return ModelConfig(
        name=f"llm-{preset}", family="dense", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
        d_ff=p["d_ff"], vocab_size=p["vocab"])


def train_job(preset: str, steps: int, ckpt_dir: str, seed: int = 0):
    """The unit the Syndeo scheduler dispatches to a pod slice."""
    p = PRESETS[preset]
    cfg = make_cfg(preset)
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init_params, jax.random.PRNGKey(0))))
    opt = make_optimizer("adamw")
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=p["seq"], global_batch=p["batch"],
                                    seed=seed))
    tcfg = TrainerConfig(num_steps=steps or p["steps"], ckpt_every=20,
                         log_every=5, n_microbatches=2)
    trainer = Trainer(model, opt, pipe, Checkpointer(ckpt_dir), tcfg)
    trainer.install_signal_handler()
    t0 = time.time()
    trainer.run(trainer.init_or_restore(seed=seed))
    return {"params": int(n_params), "history": trainer.history,
            "wall_s": time.time() - t0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--no-cluster", action="store_true",
                    help="run the job inline instead of via Syndeo")
    args = ap.parse_args()

    if args.no_cluster:
        out = train_job(args.preset, args.steps, args.ckpt_dir)
    else:
        with SyndeoCluster() as c:
            c.add_worker(resources={"cpu": 1.0, "tpu_slice": 1.0})
            job = c.submit(train_job, args.preset, args.steps, args.ckpt_dir,
                           resources={"tpu_slice": 1.0}, group="train",
                           max_retries=2)   # restarts resume from checkpoint
            out = c.get(job, timeout=36000)

    print(f"model: {out['params']:,} params; wall {out['wall_s']:.1f}s")
    for rec in out["history"]:
        print(f"  step {rec['step']:4d} loss {rec['loss']:.4f} "
              f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.2f}")
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
