"""Logical-axis sharding: model code annotates activations/params with
*logical* axis names; the launcher binds them to physical mesh axes.

Outside a binding (unit tests on 1 device) every constraint is a no-op, so
the same model code runs everywhere -- the Syndeo 'write once, deploy
anywhere' principle applied to sharding.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, None, Tuple[str, ...]]

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, Tuple[str, ...]]]]:
    return getattr(_state, "binding", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, Tuple[str, ...]]):
    """Bind logical axis names to physical mesh axes for the enclosed scope."""
    prev = _current()
    _state.binding = (mesh, rules)
    try:
        yield
    finally:
        _state.binding = prev


def resolve(spec: Sequence[Logical]) -> Optional[P]:
    """Logical spec -> PartitionSpec under the current binding (None if unbound)."""
    bound = _current()
    if bound is None:
        return None
    _, rules = bound
    out = []
    for ax in spec:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            phys: Tuple[str, ...] = ()
            for a in ax:
                phys = phys + rules.get(a, ())
            out.append(phys if phys else None)
        else:
            phys = rules.get(ax, ())
            out.append(phys if phys else None)
    return P(*out)


def _guard_divisibility(mesh: Mesh, shape, pspec: P) -> P:
    """Drop mesh axes from dims they don't divide (e.g. 8 KV heads on a
    16-way model axis fall back to replication -- DESIGN.md head-divisibility
    fallback). Keeps every constraint legal for any arch/mesh combination."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(pspec) + (None,) * (len(shape) - len(pspec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        kept = []
        for a in axes:
            if dim % (total * sizes[a]) == 0:
                kept.append(a)
                total *= sizes[a]
        if not kept:
            out.append(None)
        elif isinstance(entry, tuple):
            out.append(tuple(kept))
        else:
            out.append(kept[0])   # bare axis stays bare: P('x') != P(('x',))
    return P(*out)


def constrain(x: jax.Array, *spec: Logical) -> jax.Array:
    """with_sharding_constraint against logical axes; no-op when unbound."""
    bound = _current()
    if bound is None:
        return x
    mesh, _ = bound
    pspec = resolve(spec)
    if pspec is None:
        return x
    pspec = _guard_divisibility(mesh, x.shape, pspec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


def named_sharding(*spec: Logical) -> Optional[NamedSharding]:
    bound = _current()
    if bound is None:
        return None
    mesh, _ = bound
    return NamedSharding(mesh, resolve(spec))


# Default bindings ------------------------------------------------------------

def single_pod_rules() -> Dict[str, Tuple[str, ...]]:
    return {
        "batch": ("data",),
        "model": ("model",),
        "expert": ("data",),   # EP over the DP axis (all-to-all dispatch)
        "ep_batch": (),        # group axis in expert-major layout
        "fsdp": ("data",),     # weight sharding for the largest models
        "pod_fsdp": (),        # expert-weight sharding across pods
        "seq": (),             # sequence parallelism: off by default
    }


def multi_pod_rules() -> Dict[str, Tuple[str, ...]]:
    return {
        "batch": ("pod", "data"),
        "model": ("model",),
        "expert": ("data",),   # EP within a pod; experts replicated across pods
        "ep_batch": ("pod",),  # expert-major keeps pod-locality (a2a stays in-pod)
        "fsdp": ("pod", "data"),
        "pod_fsdp": ("pod",),  # expert weights gather across pods per layer
        "seq": (),
    }
