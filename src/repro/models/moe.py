"""Mixture-of-Experts FFN with static-shape, sort-based token dispatch.

Parallelism (GShard-style, adapted to the (pod, data, model) mesh):
  * tokens are processed in G groups; the G axis is sharded over the DP axis
    ("batch" logical axis),
  * experts are sharded over the "expert" logical axis (bound to the `data`
    mesh axis), so the group-major -> expert-major transpose lowers to an
    all-to-all *within* a pod while the pod axis stays data-parallel,
  * for very large experts (arctic-480b) d_ff is additionally sharded over
    `model` (expert tensor parallelism) -> all-reduce over `model` after the
    down-projection.

Static shapes: capacity-factor routing. Tokens over capacity are dropped
(standard GShard behaviour); dropped tokens pass through the residual only.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.axes import constrain

F32 = jnp.float32


def capacity(cfg: ModelConfig, n_tokens_per_group: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens_per_group * m.top_k / m.n_experts)
    return max(4, -(-c // 4) * 4)  # >=4, aligned to 4


def init_moe_layer(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * std).astype(F32),
        "w1": (jax.random.normal(ks[1], (e, d, f)) * std).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, d, f)) * std).astype(dtype),
        "w2": (jax.random.normal(ks[3], (e, f, d)) * std).astype(dtype),
    }
    if m.dense_residual_ff:
        fr = m.dense_residual_ff
        kd = jax.random.split(ks[4], 3)
        p["dense"] = {
            "w1": (jax.random.normal(kd[0], (d, fr)) * std).astype(dtype),
            "w3": (jax.random.normal(kd[1], (d, fr)) * std).astype(dtype),
            "w2": (jax.random.normal(kd[2], (fr, d)) * std).astype(dtype),
        }
    return p


def _dispatch_one_group(x, logits, top_k: int, cap: int):
    """Sort-based dispatch for one token group.

    x: (N, d), logits: (N, E)  ->  (slots (E*C, d), combine info)
    """
    n, e = logits.shape
    gates = jax.nn.softmax(logits.astype(F32), axis=-1)
    top_g, top_e = jax.lax.top_k(gates, top_k)            # (N, k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                            # (N*k,)
    order = jnp.argsort(flat_e, stable=True)              # slots sorted by expert
    sorted_e = flat_e[order]
    # rank of each sorted slot within its expert
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(n * top_k) - starts[sorted_e]
    valid = rank < cap
    dest = jnp.where(valid, sorted_e * cap + rank, e * cap)  # dump row at end

    token_of_slot = order // top_k
    rows = x[token_of_slot] * valid[:, None].astype(x.dtype)
    slots = jnp.zeros((e * cap + 1, x.shape[-1]), x.dtype).at[dest].add(rows)
    slots = slots[:-1]                                    # (E*C, d)

    # combine metadata: for each original (token, k) its slot id (or dump)
    inv = jnp.zeros((n * top_k,), jnp.int32).at[order].set(
        jnp.where(valid, dest, e * cap).astype(jnp.int32))
    return slots, inv, top_g, gates


def moe_ffn(p, x: jax.Array, cfg: ModelConfig, n_groups: int) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) -> (y: (B, T, d), aux_loss scalar)."""
    m = cfg.moe
    B, T, d = x.shape
    e, k = m.n_experts, m.top_k
    N = B * T
    assert N % n_groups == 0, (N, n_groups)
    ng = N // n_groups
    cap = capacity(cfg, ng)

    xg = x.reshape(n_groups, ng, d)
    xg = constrain(xg, "batch", None, None)
    logits = jnp.einsum("gnd,de->gne", xg.astype(F32), p["router"])

    slots, inv, top_g, gates = jax.vmap(
        lambda xx, ll: _dispatch_one_group(xx, ll, k, cap))(xg, logits)
    # slots: (G, E*C, d) group-major, sharded over batch
    D = slots.reshape(n_groups, e, cap, d)
    D = constrain(D, "batch", None, None, None)
    # ---- EP all-to-all: group-major -> expert-major --------------------------
    De = jnp.swapaxes(D, 0, 1)                             # (E, G, C, d)
    De = constrain(De, "expert", "ep_batch", None, None)

    h1 = jnp.einsum("egcd,edf->egcf", De, p["w1"])
    h3 = jnp.einsum("egcd,edf->egcf", De, p["w3"])
    h = jax.nn.silu(h1.astype(F32)).astype(h1.dtype) * h3
    out_e = jnp.einsum("egcf,efd->egcd", h, p["w2"])       # all-reduce over model (expert-TP)
    out_e = constrain(out_e, "expert", "ep_batch", None, None)

    # ---- all-to-all back: expert-major -> group-major ------------------------
    out_g = jnp.swapaxes(out_e, 0, 1).reshape(n_groups, e * cap, d)
    out_g = constrain(out_g, "batch", None, None)

    # combine: gather each (token, k) slot row, weight by gate
    pad = jnp.concatenate([out_g, jnp.zeros((n_groups, 1, d), out_g.dtype)], axis=1)
    picked = jax.vmap(lambda rows, idx: rows[idx])(pad, inv)   # (G, N_g*k, d)
    picked = picked.reshape(n_groups, ng, k, d)
    y = jnp.sum(picked * top_g[..., None].astype(picked.dtype), axis=2)
    y = y.reshape(B, T, d)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(gates, axis=(0, 1))                      # (E,) mean router prob
    assign = jax.nn.one_hot(jnp.argmax(gates, -1), e, dtype=F32)
    ce = jnp.mean(assign, axis=(0, 1))
    aux = e * jnp.sum(me * ce) * m.aux_loss_weight

    if "dense" in p:
        dp = p["dense"]
        h1 = jnp.einsum("btd,df->btf", x, dp["w1"])
        h3 = jnp.einsum("btd,df->btf", x, dp["w3"])
        h = jax.nn.silu(h1.astype(F32)).astype(h1.dtype) * h3
        y = y + jnp.einsum("btf,fd->btd", h, dp["w2"])

    return constrain(y, "batch", None, None), aux
