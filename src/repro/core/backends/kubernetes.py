"""Kubernetes backend (paper §III-E: cloud deployment).

Renders a head Service + head Pod + worker Deployment running the same
Apptainer image (via the sif->OCI bridge or directly as an OCI image). The
rendezvous is a ConfigMap-backed shared mount -- same write-then-poll
protocol as the Slurm shared filesystem.

Elasticity is *declarative*: a HorizontalPodAutoscaler scales the worker
Deployment on the scheduler's own demand signals (backlog per worker +
busy-worker utilization), exported through a custom-metrics adapter that
polls the head's authenticated `stats` op. The autoscaler's
provision/release hooks only nudge the HPA's replica floor (`kubectl
patch`) -- no imperative `kubectl scale` anywhere, so the HPA and the
Syndeo autoscaler can never fight over the replica count."""
from __future__ import annotations

from typing import Dict, List

from repro.core.backends.base import AllocationRequest, Backend


class KubernetesBackend(Backend):
    name = "kubernetes"
    supports_elastic = True

    def render_artifacts(self, req: AllocationRequest,
                         cluster_id: str) -> Dict[str, str]:
        image = self.container.image.replace(".sif", ":latest")
        manifest = f"""\
apiVersion: v1
kind: Service
metadata:
  name: syndeo-head-{cluster_id}
spec:
  selector:
    app: syndeo-{cluster_id}
    role: head
  ports:
  - port: 6379
---
apiVersion: v1
kind: Pod
metadata:
  name: syndeo-head-{cluster_id}
  labels: {{app: syndeo-{cluster_id}, role: head}}
spec:
  securityContext:
    runAsNonRoot: true            # the Apptainer principle, K8s-enforced
    runAsUser: 1000
  containers:
  - name: head
    image: {image}
    command: ["{self.container.entrypoint.split()[0]}"]
    args: ["-m", "repro.core.worker", "--role", "head",
           "--rendezvous", "{req.shared_dir}", "--cluster-id", "{cluster_id}"]
    resources:
      requests: {{cpu: "{req.cpus_per_node}"}}
    volumeMounts:
    - name: rdv
      mountPath: {req.shared_dir}
  volumes:
  - name: rdv
    persistentVolumeClaim: {{claimName: syndeo-shared}}
---
apiVersion: apps/v1
kind: Deployment
metadata:
  name: syndeo-workers-{cluster_id}
spec:
  replicas: {req.nodes - 1}
  selector:
    matchLabels: {{app: syndeo-{cluster_id}, role: worker}}
  template:
    metadata:
      labels: {{app: syndeo-{cluster_id}, role: worker}}
    spec:
      securityContext:
        runAsNonRoot: true
        runAsUser: 1000
      containers:
      - name: worker
        image: {image}
        command: ["{self.container.entrypoint.split()[0]}"]
        # --blob-host: the p2p blob server advertises the pod IP (downward
        # API), so peer workers dial this pod instead of their own loopback
        args: ["-m", "repro.core.worker", "--role", "worker",
               "--rendezvous", "{req.shared_dir}", "--cluster-id", "{cluster_id}",
               "--blob-host", "$(POD_IP)"]
        env:
        - name: POD_IP
          valueFrom: {{fieldRef: {{fieldPath: status.podIP}}}}
        resources:
          requests: {{cpu: "{req.cpus_per_node}"}}
        volumeMounts:
        - name: rdv
          mountPath: {req.shared_dir}
      volumes:
      - name: rdv
        persistentVolumeClaim: {{claimName: syndeo-shared}}
"""
        return {f"syndeo_{cluster_id}.yaml": manifest,
                f"syndeo_hpa_{cluster_id}.yaml":
                    self._hpa_manifest(req, cluster_id),
                f"syndeo_metrics_adapter_{cluster_id}.yaml":
                    self._metrics_adapter_manifest(req, cluster_id)}

    def _hpa_manifest(self, req: AllocationRequest, cluster_id: str) -> str:
        """HorizontalPodAutoscaler on the scheduler's demand signals: the
        declarative twin of AutoscalerConfig's queue-depth and
        target-utilization policies (backlog per worker ~ 2, busy fraction
        ~ 0.75)."""
        return f"""\
apiVersion: autoscaling/v2
kind: HorizontalPodAutoscaler
metadata:
  name: syndeo-workers-{cluster_id}
spec:
  scaleTargetRef:
    apiVersion: apps/v1
    kind: Deployment
    name: syndeo-workers-{cluster_id}
  minReplicas: 1
  maxReplicas: {max(req.nodes * 4, req.nodes)}
  metrics:
  # READY+PENDING backlog per worker, from the head's stats op via the
  # custom-metrics adapter (queue_depth_per_worker policy, target 2)
  - type: Pods
    pods:
      metric:
        name: syndeo_backlog_per_worker
      target:
        type: AverageValue
        averageValue: "2"
  # busy-worker fraction (target_utilization policy, target 0.75 == 750m)
  - type: Pods
    pods:
      metric:
        name: syndeo_busy_fraction
      target:
        type: AverageValue
        averageValue: "750m"
  behavior:
    scaleDown:
      # the head drains pods (migrating hot objects) before they die, so
      # give the drain plane time between downscale steps
      stabilizationWindowSeconds: 120
      policies:
      - type: Pods
        value: 8
        periodSeconds: 60
    scaleUp:
      policies:
      - type: Pods
        value: 16
        periodSeconds: 15
"""

    def _metrics_adapter_manifest(self, req: AllocationRequest,
                                  cluster_id: str) -> str:
        """Custom-metrics adapter: a small deployment that polls the head's
        HMAC-authenticated `stats` op and serves the two scheduler signals
        under custom.metrics.k8s.io for the HPA to consume."""
        image = self.container.image.replace(".sif", ":latest")
        return f"""\
apiVersion: apps/v1
kind: Deployment
metadata:
  name: syndeo-metrics-adapter-{cluster_id}
spec:
  replicas: 1
  selector:
    matchLabels: {{app: syndeo-{cluster_id}, role: metrics-adapter}}
  template:
    metadata:
      labels: {{app: syndeo-{cluster_id}, role: metrics-adapter}}
    spec:
      securityContext:
        runAsNonRoot: true
        runAsUser: 1000
      containers:
      - name: adapter
        image: {image}
        # polls the head's sealed `metrics` op (scheduler backlog, busy
        # fraction, tenant shares) and republishes it as custom metrics.
        # API aggregation dials the adapter over TLS, so it serves HTTPS
        # with the mounted serving cert (Secret syndeo-metrics-serving-cert,
        # e.g. issued by cert-manager or the cluster CA).
        command: ["python"]
        args: ["-m", "repro.core.metrics_adapter",
               "--rendezvous", "{req.shared_dir}",
               "--cluster-id", "{cluster_id}",
               "--metrics",
               "syndeo_backlog_per_worker,syndeo_busy_fraction",
               "--tls-cert", "/var/run/serving-cert/tls.crt",
               "--tls-key", "/var/run/serving-cert/tls.key"]
        volumeMounts:
        - name: rdv
          mountPath: {req.shared_dir}
        - name: serving-cert
          mountPath: /var/run/serving-cert
          readOnly: true
      volumes:
      - name: rdv
        persistentVolumeClaim: {{claimName: syndeo-shared}}
      - name: serving-cert
        secret: {{secretName: syndeo-metrics-serving-cert}}
---
apiVersion: v1
kind: Service
metadata:
  name: syndeo-metrics-adapter-{cluster_id}
spec:
  selector:
    app: syndeo-{cluster_id}
    role: metrics-adapter
  ports:
  - port: 443
    targetPort: 6443
---
apiVersion: apiregistration.k8s.io/v1
kind: APIService
metadata:
  name: v1beta1.custom.metrics.k8s.io
spec:
  service:
    name: syndeo-metrics-adapter-{cluster_id}
    namespace: default
  group: custom.metrics.k8s.io
  version: v1beta1
  insecureSkipTLSVerify: true
  groupPriorityMinimum: 100
  versionPriority: 100
"""

    # -- elasticity: nudge the HPA floor (declarative; never kubectl scale) ----

    def provision_workers(self, req: AllocationRequest, cluster_id: str,
                          count: int) -> Dict[str, str]:
        hpa = f"syndeo-workers-{cluster_id}"
        script = f"""\
#!/bin/bash
set -euo pipefail
# elastic scale-up: raise the HPA's replica floor by {count}. The HPA (fed
# by the scheduler's backlog/utilization custom metrics) owns the actual
# replica count -- the floor only guarantees the capacity the inner
# autoscaler asked for arrives even while metrics are still catching up.
CUR=$(kubectl get hpa {hpa} -o jsonpath='{{.spec.minReplicas}}')
MAX=$(kubectl get hpa {hpa} -o jsonpath='{{.spec.maxReplicas}}')
NEW=$((CUR + {count})); [ "$NEW" -le "$MAX" ] || NEW=$MAX
kubectl patch hpa {hpa} --type merge \\
  -p "{{\\"spec\\":{{\\"minReplicas\\":$NEW}}}}"
"""
        return {f"scale_up_{cluster_id}_{count}.sh": script}

    def release_workers(self, req: AllocationRequest, cluster_id: str,
                        worker_ids: List[str],
                        drain_deadline_s: float = 0.0) -> Dict[str, str]:
        hpa = f"syndeo-workers-{cluster_id}"
        # worker id == pod hostname == pod name in this backend (the worker
        # process registers under its hostname)
        annotates = "\n".join(
            f"kubectl annotate pod {wid} "
            f"controller.kubernetes.io/pod-deletion-cost=-999 "
            f"--overwrite || true"
            for wid in worker_ids)
        grace = int(drain_deadline_s) if drain_deadline_s > 0 else 0
        # pod deletion is asynchronous through the HPA: its scaleDown
        # stabilization window is 120s (see _hpa_manifest), so the wait
        # must cover window + drain grace before giving up
        wait_s = grace + 180
        script = f"""\
#!/bin/bash
set -euo pipefail
# graceful scale-down: the scheduler already drained these pods (no new
# placements, hot objects migrated). Mark them cheapest to delete, then
# lower the HPA floor -- with the demand metrics already low the HPA
# shrinks the Deployment after its 120s stabilization window and the
# ReplicaSet controller removes exactly the marked pods, each with a
# {grace}s termination grace for anything still exiting.
{annotates}
CUR=$(kubectl get hpa {hpa} -o jsonpath='{{.spec.minReplicas}}')
NEW=$((CUR - {len(worker_ids)})); [ "$NEW" -ge 1 ] || NEW=1
kubectl patch hpa {hpa} --type merge \\
  -p "{{\\"spec\\":{{\\"minReplicas\\":$NEW}}}}"
# sleep {grace}s drain grace first: a drained worker that self-exits early
# would otherwise be restarted by the ReplicaSet before the HPA shrinks
sleep {grace}
kubectl wait --for=delete {' '.join(f'pod/{wid}' for wid in worker_ids)} \\
  --timeout={wait_s}s || true
"""
        return {f"scale_down_{cluster_id}.sh": script}
