"""known-good: every mutating branch verifies its ticket first."""


class TicketedServer:
    def __init__(self, store):
        self.store = store

    def _verify(self, header, right):
        raise NotImplementedError

    def dispatch(self, header, blob):
        op = header.get("op")
        if op == "put":
            self._verify(header, "put")
            self.store.import_blob(header["object"], blob)
            return {"ok": True}
        if op == "del":
            self._verify(header, "del")
            self.store.delete(header["object"])
            return {"ok": True}
        return {"ok": False, "error": f"bad op {op}"}
