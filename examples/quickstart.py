"""Quickstart: bring up a Syndeo cluster (the paper's four phases), run a
dependency-driven workload, and survive a worker failure.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ContainerSpec, SyndeoCluster
from repro.core.backends.base import AllocationRequest
from repro.core.backends.slurm import SlurmBackend


def preprocess(seed: int):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(1000,))


def reduce_stats(*chunks):
    data = np.concatenate(chunks)
    return {"mean": float(data.mean()), "std": float(data.std())}


def main():
    # ---- phase 1: the container spec (renderable for any backend) ----------
    spec = ContainerSpec(env={"OMP_NUM_THREADS": "1"})
    artifacts = SlurmBackend(spec).render_artifacts(
        AllocationRequest(nodes=4), cluster_id="demo")
    print(f"phase 1: container + launch artifacts -> {sorted(artifacts)}")

    # ---- phases 2-4: head up, workers join, jobs run ------------------------
    with SyndeoCluster(container=spec) as cluster:
        for _ in range(4):
            cluster.add_worker()
        print(f"phase 2-3: head {cluster.cluster_id} up, "
              f"{len(cluster.scheduler.workers)} workers joined")

        # fan out producers; the consumer starts when its deps are met
        producers = [cluster.submit(preprocess, s, group="prep")
                     for s in range(8)]
        refs = [cluster.scheduler.graph.tasks[t.id] for t in producers]
        cluster.wait_all(producers)
        dep_refs = [cluster.scheduler.graph.tasks[t.id].output
                    for t in producers]
        consumer = cluster.submit(reduce_stats, deps=dep_refs, group="reduce")
        print("phase 4: aggregated ->", cluster.get(consumer))

        # elasticity: lose a worker mid-stream, work still completes
        more = [cluster.submit(preprocess, s) for s in range(20)]
        cluster.remove_worker(next(iter(cluster._queues)))
        cluster.wait_all(more)
        print(f"fault tolerance: finished {len(more)} tasks after losing a "
              f"worker (retries={cluster.scheduler.stats['retried']})")


if __name__ == "__main__":
    main()
