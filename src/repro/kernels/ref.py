"""Pure-jnp oracles for every Pallas kernel (exact, unblocked math).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                  valid_len=None, kv_scale=None, v_scale=None):
    """q (B,Hq,Tq,D); k/v (B,Hkv,Tk,D) [+ optional int8 scales (B,Hkv,Tk,1)]."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    R = Hq // Hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if kv_scale is not None:
        kf = kf * kv_scale
    if v_scale is not None:
        vf = vf * v_scale
    kf = jnp.repeat(kf, R, axis=1)
    vf = jnp.repeat(vf, R, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / math.sqrt(D)
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)   # align ends (decode offset)
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    mask = jnp.broadcast_to(mask, (B, 1, Tq, Tk))
    if valid_len is not None:
        mask = mask & (kpos[None, None] < valid_len[:, None, None, None])
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def moe_gmm_ref(x, w):
    """Grouped matmul oracle. x (E, C, d) @ w (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(x, dt, A, Bm, Cm, chunk):
    """Chunked-SSD oracle via the *sequential* recurrence (ground truth).

    x (B,T,H,P); dt (B,T,H); A (H,); Bm/Cm (B,T,G,N) -> y (B,T,H,P).
    """
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt * A)                         # (B,H)
        S = dA[:, :, None, None] * S + jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3)


def mlstm_ref(q, k, v, ig, lf):
    """Sequential stabilized mLSTM recurrence (ground truth).

    q/k/v (B,T,H,Dh); ig/lf (B,T,H) (input-gate preact, log-sigmoid forget).
    """
    B, T, H, Dh = q.shape
    scale = Dh ** -0.5

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        m_new = jnp.maximum(ft + m, it)
        fp = jnp.exp(ft + m - m_new)
        ip = jnp.exp(it - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", kt, vt)
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt * scale, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt * scale, n)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    n0 = jnp.zeros((B, H, Dh), jnp.float32)
    m0 = jnp.full((B, H), -30.0, jnp.float32)
    xs = tuple(a.astype(jnp.float32).transpose(1, 0, *range(2, a.ndim))
               for a in (q, k, v, ig, lf))
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3)
