"""Decentralized data plane benchmark: peer-to-peer vs head-relay.

The seed runtime relayed every dependency blob and task result through the
head's single socket, so aggregate data-plane bandwidth was capped by one
NIC -- the control/data-plane conflation that collapses network-bound
scaling (paper Table II's Humanoid row). The refactored stack splits a
metadata-only head directory from a worker-side blob plane; this benchmark
measures exactly that split on the REAL Scheduler/ObjectStore code under
the sim's per-link cost model:

1. *Shuffle*: N producers each emit one fat object; M consumers each
   depend on all N outputs (N x M x size of dep traffic). Under
   `data_plane="relay"` every byte serializes on the head link; under
   `"p2p"` transfers overlap across worker NICs. Reported per worker
   count: makespan, head-relayed payload bytes (p2p must be ~0, relay
   ~everything), and aggregate dep traffic.

2. *Drain*: a worker solely holding fat hot objects is drained while the
   survivors' stores are nearly too small. The bandwidth-aware planner
   (scheduler._dispatch_moves) must land every object without overflowing
   any destination store and spread the moves across links instead of
   convoying behind one survivor.

3. *Drain plane* (p2p vs relay): the same fat-object drain executed as
   direct worker->worker pushes (the two-phase migrate protocol) vs
   relayed through the head's serialized NIC. p2p must move ZERO bytes
   over the head's link during the drain and finish no slower than the
   relay -- scale-down under load is exactly when the head's NIC must
   stay out of the data path.

4. *Head plane* (sharded + batched control plane): decision throughput of
   the head scheduler under a steady-state arrival stream at large worker
   counts -- the seed paid a full-graph ready scan plus a per-finish twin
   scan per event under the one big lock; the sharded ready queues make
   each event O(backlog) heap work. Plus the wire side: a worker's
   result ack piggybacks on its poll as one `batch` frame, halving
   control round trips on the hot path.

5. *Broadcast + batched moves + delta spill* (the data-plane throughput
   layer): a 32-consumer fat-object broadcast through the binomial tree
   vs N serialized pushes from one NIC (tree must be >= 3x faster with
   zero head payload bytes); a multi-object drain push to one
   destination as ONE multi-blob frame vs per-move connections over
   real sockets (>= 2x fewer connections/round trips at equal bytes);
   and spill churn through the content-chunked delta tier vs whole-blob
   rewrites (measured bytes-written reduction).

Run:  PYTHONPATH=src python benchmarks/dataplane_bench.py [--quick]
      PYTHONPATH=src python benchmarks/dataplane_bench.py --dataplane-smoke
      PYTHONPATH=src python benchmarks/dataplane_bench.py --drain-p2p-smoke
      PYTHONPATH=src python benchmarks/dataplane_bench.py --headplane-smoke
      PYTHONPATH=src python benchmarks/dataplane_bench.py --broadcast-smoke
"""
from __future__ import annotations

import argparse
import pickle
import random
import tempfile
import time
from collections import deque
from typing import Dict, List

from repro.core import (NodeStore, ObjectRef, Scheduler, SchedulerConfig,
                        SimCluster, SimCostModel, SyndeoCluster, TaskSpec,
                        TransferTicket, WorkerInfo)
from repro.core.object_store import GlobalObjectStore, TCPTransport
from repro.core.worker import (BlobServer, HeadServer, push_batch_with_retry,
                               push_with_retry)

MB = 1_000_000


# ------------------------------------------------------------------- shuffle


def _noop():
    return None


def shuffle_run(data_plane: str, n_workers: int, n_producers: int,
                n_consumers: int, obj_bytes: int,
                bandwidth_Bps: float = 1.0e9) -> Dict[str, float]:
    """One shuffle wave under the given data plane; returns the metrics."""
    cost = SimCostModel(
        task_time_s=lambda s: 0.02,
        result_bytes=lambda s: float(obj_bytes) if s.group == "produce"
        else 1024.0,
        jitter=0.0,
        head_bandwidth_Bps=bandwidth_Bps,
        node_bandwidth_Bps=bandwidth_Bps,
        data_plane=data_plane,
        result_location="worker" if data_plane == "p2p" else "head")
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    sim.add_workers(n_workers)
    t0 = sim.now
    producers = [sim.submit(TaskSpec(fn=_noop, name=f"p{i}", group="produce"))
                 for i in range(n_producers)]
    sim.run()
    outputs: List[ObjectRef] = []
    for p in producers:
        task = sim.scheduler.graph.tasks[p.id]
        assert task.output is not None, f"producer {p.id} did not finish"
        outputs.append(task.output)
    consumers = [sim.submit(TaskSpec(fn=_noop, name=f"c{i}", group="consume"),
                            deps=list(outputs))
                 for i in range(n_consumers)]
    sim.run()
    for cns in consumers:
        assert sim.scheduler.graph.tasks[cns.id].output is not None
    dep_traffic = float(n_consumers) * sum(o.size for o in outputs)
    return {"makespan_s": sim.now - t0,
            "head_relayed_bytes": float(
                sim.store.stats["head_relayed_bytes"]),
            "dep_traffic_bytes": dep_traffic}


def bench_shuffle(worker_counts: List[int], obj_bytes: int) -> List[Dict]:
    rows = []
    for n in worker_counts:
        relay = shuffle_run("relay", n, n, n, obj_bytes)
        p2p = shuffle_run("p2p", n, n, n, obj_bytes)
        rows.append({"workers": n, "relay": relay, "p2p": p2p})
    return rows


def print_shuffle(rows: List[Dict]):
    print("\n== shuffle (N producers x N consumers, fat objects) ==")
    print(f"{'workers':>8} {'relay s':>9} {'p2p s':>9} {'speedup':>8} "
          f"{'relay head MB':>14} {'p2p head MB':>12}")
    for r in rows:
        speed = r["relay"]["makespan_s"] / max(r["p2p"]["makespan_s"], 1e-12)
        print(f"{r['workers']:>8} {r['relay']['makespan_s']:>9.3f} "
              f"{r['p2p']['makespan_s']:>9.3f} {speed:>7.1f}x "
              f"{r['relay']['head_relayed_bytes'] / MB:>14.1f} "
              f"{r['p2p']['head_relayed_bytes'] / MB:>12.1f}")


# --------------------------------------------------------------------- drain


def drain_run(n_objects: int = 8, obj_bytes: int = 8 * MB,
              n_survivors: int = 4,
              survivor_capacity: int = 24 * MB) -> Dict[str, object]:
    """Drain a worker solely holding `n_objects` fat hot objects while the
    survivors can each take only a few -- the bandwidth-aware planner must
    pack under capacity and spread across links."""
    cost = SimCostModel(task_time_s=lambda s: 0.01, jitter=0.0,
                        data_plane="p2p", result_location="worker",
                        migration_bandwidth_Bps=1.0e9)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    victim = sim.add_workers(1, capacity_bytes=1 << 30)[0]
    survivors = sim.add_workers(n_survivors,
                                capacity_bytes=survivor_capacity)
    refs = [sim.store.put(victim, bytearray(obj_bytes))
            for _ in range(n_objects)]     # refcount 1 each: hot
    t0 = sim.now
    sim.drain_worker_at(victim, t=0.0)
    sim.run()
    assert victim not in sim.scheduler.workers, "drain did not finish"
    dests = {}
    for r in refs:
        locs = sim.store.locations(r)
        assert locs, f"hot object {r.id} lost by the drain"
        for n in locs:
            dests[n] = dests.get(n, 0) + r.size
    over = {n: (used, sim.store._nodes[n].capacity)
            for n, used in dests.items()
            if n in survivors
            and sim.store._nodes[n].used_bytes
            > sim.store._nodes[n].capacity}
    return {"drain_s": sim.now - t0,
            "destinations": sorted(d for d in dests if d != victim),
            "bytes_by_destination": dests,
            "over_capacity": over,
            "reconstructions": sim.store.stats["reconstructions"],
            "migrated": sim.store.stats["migrations"]}


def print_drain(res: Dict[str, object]):
    print("\n== bandwidth-aware drain (fat objects, tight survivors) ==")
    print(f"  drain latency      : {res['drain_s']:.3f} s (virtual)")
    print(f"  migrations         : {res['migrated']}")
    print(f"  destinations used  : {len(res['destinations'])} "
          f"({', '.join(res['destinations'])})")
    for n, b in sorted(res["bytes_by_destination"].items()):
        print(f"    {n:>6}: {b / MB:.1f} MB")
    print(f"  over-capacity dests: {res['over_capacity'] or 'none'}")
    print(f"  reconstructions    : {res['reconstructions']}")


# --------------------------------------------------- drain plane: p2p vs relay


def drain_plane_run(data_plane: str, n_objects: int = 8,
                    obj_bytes: int = 8 * MB,
                    n_survivors: int = 3) -> Dict[str, float]:
    """Drain a worker solely holding fat hot objects under the given
    migration plane; report drain latency and the bytes the head's NIC
    relayed *for the drain itself* (p2p direct pushes must report 0)."""
    cost = SimCostModel(task_time_s=lambda s: 0.01, jitter=0.0,
                        data_plane=data_plane,
                        result_location="worker" if data_plane == "p2p"
                        else "head",
                        head_bandwidth_Bps=1.0e9,
                        node_bandwidth_Bps=1.0e9,
                        migration_bandwidth_Bps=1.0e9)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    victim = sim.add_workers(1, capacity_bytes=1 << 30)[0]
    sim.add_workers(n_survivors, capacity_bytes=1 << 30)
    refs = [sim.store.put(victim, bytearray(obj_bytes))
            for _ in range(n_objects)]     # refcount 1 each: hot
    head0 = sim.store.stats["head_relayed_bytes"]
    t0 = sim.now
    sim.drain_worker_at(victim, t=0.0)
    sim.run()
    assert victim not in sim.scheduler.workers, "drain did not finish"
    for r in refs:
        assert sim.store.locations(r), f"hot object {r.id} lost"
    return {"drain_s": sim.now - t0,
            "head_relayed_bytes": float(
                sim.store.stats["head_relayed_bytes"] - head0),
            "moved_bytes": float(n_objects * obj_bytes),
            "migrated": float(sim.store.stats["migrations"]),
            "reconstructions": float(sim.store.stats["reconstructions"])}


def print_drain_plane(p2p: Dict[str, float], relay: Dict[str, float]):
    print("\n== drain plane: direct p2p pushes vs head relay ==")
    print(f"{'plane':>8} {'drain s':>9} {'head MB':>9} {'moved MB':>9}")
    for name, r in (("p2p", p2p), ("relay", relay)):
        print(f"{name:>8} {r['drain_s']:>9.3f} "
              f"{r['head_relayed_bytes'] / MB:>9.1f} "
              f"{r['moved_bytes'] / MB:>9.1f}")


def drain_p2p_smoke() -> int:
    """CI gate: during a drain, direct p2p moves put ZERO bytes on the
    head's link while the relay plane pays for every byte -- at no
    makespan cost (p2p drain <= relay drain)."""
    p2p = drain_plane_run("p2p")
    relay = drain_plane_run("relay")
    print_drain_plane(p2p, relay)
    ok = True
    if p2p["head_relayed_bytes"] != 0:
        print(f"FAIL: p2p drain relayed {p2p['head_relayed_bytes']:.0f} "
              f"bytes through the head")
        ok = False
    if relay["head_relayed_bytes"] < relay["moved_bytes"]:
        print(f"FAIL: relay drain should pay the head's NIC for every "
              f"moved byte ({relay['head_relayed_bytes']:.0f} of "
              f"{relay['moved_bytes']:.0f})")
        ok = False
    if p2p["drain_s"] > relay["drain_s"]:
        print(f"FAIL: p2p drain slower than relay "
              f"({p2p['drain_s']:.3f} vs {relay['drain_s']:.3f})")
        ok = False
    if p2p["reconstructions"] or relay["reconstructions"]:
        print("FAIL: a drain cost lineage reconstructions")
        ok = False
    print("\ndrain-p2p smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


# ------------------------------------------- head plane: sharded + batched


def decision_run(shards: int, n_workers: int, total_tasks: int,
                 backlog: int = 256, n_tenants: int = 8) -> Dict[str, float]:
    """Control-plane decision throughput: a steady-state arrival stream
    (the ready backlog is refilled as tasks finish) drives the REAL
    Scheduler event loop with `n_workers` registered workers and
    `n_tenants` tenants on the DRF fair path. No payloads, no data plane:
    this isolates the head's per-event decision cost. `shards=1` is the
    seed-equivalent baseline (full ready_tasks() graph scan per event);
    `shards>1` takes the incremental per-shard ready heaps."""
    store = GlobalObjectStore(shards=shards)
    cfg = SchedulerConfig(shards=shards, enable_speculation=False,
                          heartbeat_timeout=1e9)
    launched: deque = deque()
    sched = Scheduler(store, lambda t, w: launched.append(t.id),
                      lambda t, w: None, cfg)
    for i in range(n_workers):
        sched.add_worker(WorkerInfo(f"w{i}", {"cpu": 1.0}))
    submitted = 0

    def submit_one():
        nonlocal submitted
        sched.submit(TaskSpec(fn=_noop, name=f"t{submitted}",
                              tenant_id=f"tenant{submitted % n_tenants}"))
        submitted += 1

    t0 = time.perf_counter()
    while submitted < min(n_workers + backlog, total_tasks):
        submit_one()
    finished = 0
    while finished < total_tasks and launched:
        tid = launched.popleft()
        sched.on_task_finished(tid, ObjectRef(f"obj-{tid}"))
        finished += 1
        if submitted < total_tasks:
            submit_one()           # keep the arrival stream steady-state
    elapsed = max(time.perf_counter() - t0, 1e-9)
    assert finished == total_tasks, \
        f"decision loop stalled at {finished}/{total_tasks} (shards={shards})"
    return {"decisions_per_s": finished / elapsed,
            "elapsed_s": elapsed,
            "launched": float(sched.stats["launched"]),
            "finished": float(sched.stats["finished"])}


def wire_run(batched: bool, n_workers: int = 16,
             n_tasks: int = 400) -> Dict[str, float]:
    """Control-wire round trips on the hot result/poll path, measured
    through the in-process HeadServer.dispatch: `batched` folds each
    worker's result_meta ack into its next poll as ONE `batch` frame
    (one socket round trip, one cluster-lock acquisition); the baseline
    sends them as two frames, exactly the seed wire protocol."""
    cluster = SyndeoCluster(scheduler_config=SchedulerConfig(
        shards=8 if batched else 1, enable_speculation=False,
        heartbeat_timeout=1e9))
    head = HeadServer(cluster)
    head.attach()
    try:
        wids = [head.dispatch({"op": "join", "worker": ""})["worker"]
                for _ in range(n_workers)]
        for i in range(n_tasks):
            cluster.submit(_noop, name=f"t{i}")
        frames = 0
        done = 0
        pending: Dict[str, object] = {w: None for w in wids}
        t0 = time.perf_counter()
        for _ in range(50 * (n_tasks // n_workers + 2)):
            if done >= n_tasks:
                break
            for w in wids:
                prev = pending[w]
                if batched and prev is not None:
                    r = head.dispatch({"op": "batch", "worker": w, "ops": [
                        {"op": "result_meta", "task": prev, "worker": w,
                         "size": 128},
                        {"op": "poll", "worker": w}]})
                    frames += 1
                    done += 1
                    got = r["replies"][-1]
                else:
                    if prev is not None:
                        head.dispatch({"op": "result_meta", "task": prev,
                                       "worker": w, "size": 128})
                        frames += 1
                        done += 1
                    got = head.dispatch({"op": "poll", "worker": w})
                    frames += 1
                pending[w] = got.get("task")
        elapsed = max(time.perf_counter() - t0, 1e-9)
    finally:
        head.shutdown()
        cluster.shutdown()
    assert done == n_tasks, f"wire loop stalled at {done}/{n_tasks}"
    return {"frames": float(frames), "results_per_s": done / elapsed,
            "frames_per_result": frames / max(done, 1)}


def bench_headplane(worker_counts: List[int],
                    shards: int = 8) -> List[Dict]:
    rows = []
    for n in worker_counts:
        total = max(2 * n, 1000)
        base = decision_run(1, n, total)
        sharded = decision_run(shards, n, total)
        rows.append({"workers": n, "total_tasks": total,
                     "base": base, "sharded": sharded})
    return rows


def print_headplane(rows: List[Dict], wire_single: Dict[str, float],
                    wire_batched: Dict[str, float]):
    print("\n== head plane: decisions/sec vs worker count "
          "(shards=1 baseline vs sharded) ==")
    print(f"{'workers':>8} {'tasks':>7} {'seed dec/s':>11} "
          f"{'sharded dec/s':>14} {'speedup':>8}")
    for r in rows:
        speed = (r["sharded"]["decisions_per_s"]
                 / max(r["base"]["decisions_per_s"], 1e-9))
        print(f"{r['workers']:>8} {r['total_tasks']:>7} "
              f"{r['base']['decisions_per_s']:>11.0f} "
              f"{r['sharded']['decisions_per_s']:>14.0f} {speed:>7.1f}x")
    print("\n== head wire: result ack + poll, singles vs one batch frame ==")
    print(f"{'mode':>8} {'frames/result':>14} {'results/s':>10}")
    for name, r in (("singles", wire_single), ("batch", wire_batched)):
        print(f"{name:>8} {r['frames_per_result']:>14.2f} "
              f"{r['results_per_s']:>10.0f}")


def headplane_smoke() -> int:
    """CI gate: at 1k simulated workers the sharded control plane must
    sustain >= 4x the seed's decision throughput (same launched/finished
    counts -- the shards change the cost, never the outcome), and the
    batched wire must spend meaningfully fewer frames per result."""
    rows = bench_headplane([100, 1000])
    wire_single = wire_run(batched=False)
    wire_batched = wire_run(batched=True)
    print_headplane(rows, wire_single, wire_batched)
    ok = True
    for r in rows:
        if (r["base"]["launched"] != r["sharded"]["launched"]
                or r["base"]["finished"] != r["sharded"]["finished"]):
            print(f"FAIL: sharded arm diverged at {r['workers']} workers "
                  f"(launched {r['sharded']['launched']:.0f} vs "
                  f"{r['base']['launched']:.0f})")
            ok = False
    gate = rows[-1]
    ratio = (gate["sharded"]["decisions_per_s"]
             / max(gate["base"]["decisions_per_s"], 1e-9))
    if ratio < 4.0:
        print(f"FAIL: sharded head only {ratio:.1f}x the seed at "
              f"{gate['workers']} workers (need >= 4x)")
        ok = False
    if (wire_batched["frames_per_result"]
            > 0.75 * wire_single["frames_per_result"]):
        print(f"FAIL: batch frames/result "
              f"{wire_batched['frames_per_result']:.2f} not meaningfully "
              f"below singles {wire_single['frames_per_result']:.2f}")
        ok = False
    print("\nheadplane smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


# ------------------------- broadcast trees, batched moves, delta spill


def broadcast_run(n_consumers: int = 32,
                  obj_bytes: int = 8 * MB) -> Dict[str, float]:
    """One fat object delivered to `n_consumers`: binomial tree vs N
    serialized pushes from the producer's NIC, on identical clusters."""
    out: Dict[str, float] = {"consumers": float(n_consumers)}
    for mode in ("tree", "npush"):
        sim = SimCluster(SimCostModel(jitter=0.0, data_plane="p2p",
                                      result_location="worker"))
        ids = sim.add_workers(n_consumers + 1)
        ref = sim.store.put(ids[0], bytearray(obj_bytes))
        out[f"{mode}_s"] = sim.broadcast_object(ref, ids[1:], mode=mode)
        out[f"{mode}_head_bytes"] = float(
            sim.store.stats["head_relayed_bytes"])
        if mode == "tree":
            out["rounds"] = float(sim.store.stats["broadcast_rounds"])
            out["tree_edges"] = float(sim.store.stats["tree_edges"])
            missing = [c for c in ids[1:]
                       if c not in sim.store.locations(ref)]
            assert not missing, f"broadcast lost consumers: {missing}"
    return out


class _CountingTransport(TCPTransport):
    """TCPTransport that counts connections (== _rpc calls)."""

    connections = 0

    def _rpc(self, *args, **kwargs):
        self.connections += 1
        return super()._rpc(*args, **kwargs)


def batched_move_run(n_objects: int = 16,
                     obj_bytes: int = 256 * 1024) -> Dict[str, float]:
    """Real sockets: push `n_objects` drain moves to ONE destination as
    per-move frames vs one multi-blob frame; count connections."""
    token = "bench-token"
    out: Dict[str, float] = {"objects": float(n_objects)}
    for mode in ("singles", "batched"):
        store = NodeStore("dst", capacity_bytes=1 << 30)
        srv = BlobServer(store, token, tenant_of={}.get)
        host, port = srv.endpoint
        transport = _CountingTransport(lambda n: (host, port), token, "src")
        transport.connections = 0
        items = []
        for i in range(n_objects):
            blob = pickle.dumps(bytes(obj_bytes))
            ref = ObjectRef(f"{mode}-{i}", len(blob))
            ticket = TransferTicket.grant_migrate(token, ref.id,
                                                  "dst", "src")
            items.append((ref, blob, ticket))
        t0 = time.perf_counter()
        if mode == "batched":
            verdicts, err, _ = push_batch_with_retry(transport, "dst",
                                                     items)
            assert err is None and all(v["ok"] for v in verdicts)
        else:
            for ref, blob, ticket in items:
                err, _ = push_with_retry(transport, "dst", ref, blob,
                                         ticket)
                assert err is None
        out[f"{mode}_s"] = time.perf_counter() - t0
        out[f"{mode}_connections"] = float(transport.connections)
        for ref, blob, _t in items:
            assert store.export_blob(ref) == blob
        srv.shutdown()
    return out


def delta_spill_run(generations: int = 8,
                    obj_bytes: int = 2 * MB,
                    churn_bytes: int = 64 * 1024) -> Dict[str, float]:
    """Spill churn: one fat object respilled after small mutations each
    generation. The delta tier rewrites only the touched content chunks;
    the baseline cost is a whole-blob rewrite per generation."""
    rng = random.Random(1234)
    payload = bytearray(rng.randbytes(obj_bytes))
    whole_rewrites = 0
    with tempfile.TemporaryDirectory() as tmp:
        store = NodeStore("w0", capacity_bytes=1 << 30, spill_dir=tmp)
        for gen in range(generations):
            if gen:
                at = rng.randrange(len(payload) - churn_bytes)
                payload[at:at + churn_bytes] = rng.randbytes(churn_bytes)
            blob = pickle.dumps(bytes(payload))
            ref = ObjectRef("churn", len(blob))
            store.put_blob(ref, blob)
            assert store.spill(ref)
            whole_rewrites += len(blob)
            assert store.export_blob(ref) == blob
            store.get(ref)               # promote: next gen mutates in mem
        saved = float(store.stats["delta_spill_bytes_saved"])
    return {"generations": float(generations),
            "baseline_bytes": float(whole_rewrites),
            "written_bytes": float(whole_rewrites) - saved,
            "saved_bytes": saved}


def print_broadcast(bc: Dict[str, float], mv: Dict[str, float],
                    sp: Dict[str, float]):
    print("\n== broadcast: binomial tree vs N pushes from one NIC ==")
    speed = bc["npush_s"] / max(bc["tree_s"], 1e-12)
    print(f"  consumers          : {bc['consumers']:.0f}")
    print(f"  npush makespan     : {bc['npush_s']:.4f} s (virtual)")
    print(f"  tree makespan      : {bc['tree_s']:.4f} s "
          f"({bc['rounds']:.0f} rounds, {bc['tree_edges']:.0f} edges)")
    print(f"  speedup            : {speed:.1f}x")
    print(f"  head payload bytes : tree {bc['tree_head_bytes']:.0f}, "
          f"npush {bc['npush_head_bytes']:.0f}")
    print("\n== batched move frames: one connection per destination ==")
    print(f"  objects            : {mv['objects']:.0f} (equal byte totals)")
    print(f"  per-move           : {mv['singles_connections']:.0f} "
          f"connections, {mv['singles_s'] * 1e3:.1f} ms")
    print(f"  multi-blob frame   : {mv['batched_connections']:.0f} "
          f"connection(s), {mv['batched_s'] * 1e3:.1f} ms")
    print("\n== delta-encoded spill under churn ==")
    print(f"  generations        : {sp['generations']:.0f}")
    print(f"  whole-blob rewrite : {sp['baseline_bytes'] / MB:.1f} MB")
    print(f"  delta tier wrote   : {sp['written_bytes'] / MB:.1f} MB "
          f"(saved {sp['saved_bytes'] / MB:.1f} MB)")


def broadcast_smoke() -> int:
    """CI gate for the data-plane throughput layer: the 32-consumer
    broadcast tree is >= 3x faster than the N-push baseline with zero
    head payload bytes; batched drain moves cost >= 2x fewer
    connections/round trips than per-move pushes at equal byte totals;
    and the delta spill tier measurably cuts bytes written under churn."""
    bc = broadcast_run()
    mv = batched_move_run()
    sp = delta_spill_run()
    print_broadcast(bc, mv, sp)
    ok = True
    speed = bc["npush_s"] / max(bc["tree_s"], 1e-12)
    if speed < 3.0:
        print(f"FAIL: broadcast tree only {speed:.1f}x the N-push "
              f"baseline (need >= 3x)")
        ok = False
    if bc["tree_head_bytes"] != 0:
        print(f"FAIL: broadcast put {bc['tree_head_bytes']:.0f} payload "
              f"bytes on the head's link")
        ok = False
    if mv["singles_connections"] < 2.0 * mv["batched_connections"]:
        print(f"FAIL: batched moves used {mv['batched_connections']:.0f} "
              f"connections vs {mv['singles_connections']:.0f} per-move "
              f"(need >= 2x fewer)")
        ok = False
    if sp["saved_bytes"] <= 0:
        print("FAIL: delta spill saved no bytes under churn")
        ok = False
    if sp["written_bytes"] >= sp["baseline_bytes"]:
        print(f"FAIL: delta tier wrote {sp['written_bytes']:.0f} bytes, "
              f"no better than whole-blob {sp['baseline_bytes']:.0f}")
        ok = False
    print("\nbroadcast smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


# --------------------------------------------------------------------- smoke


def smoke() -> int:
    """CI gate: p2p moves zero payload bytes through the head, beats relay
    on the shuffle at >= 4 workers, and the drain planner respects
    destination capacity while spreading moves."""
    rows = bench_shuffle([4, 8], obj_bytes=4 * MB)
    print_shuffle(rows)
    ok = True
    for r in rows:
        relay, p2p = r["relay"], r["p2p"]
        if p2p["head_relayed_bytes"] != 0:
            print(f"FAIL: p2p relayed {p2p['head_relayed_bytes']} bytes "
                  f"through the head at {r['workers']} workers")
            ok = False
        if relay["head_relayed_bytes"] < 0.95 * relay["dep_traffic_bytes"]:
            print(f"FAIL: relay should push ~all dep traffic through the "
                  f"head ({relay['head_relayed_bytes']:.0f} of "
                  f"{relay['dep_traffic_bytes']:.0f})")
            ok = False
        if p2p["makespan_s"] >= relay["makespan_s"]:
            print(f"FAIL: p2p not faster than relay at {r['workers']} "
                  f"workers ({p2p['makespan_s']:.3f} vs "
                  f"{relay['makespan_s']:.3f})")
            ok = False
    res = drain_run()
    print_drain(res)
    if res["over_capacity"]:
        print(f"FAIL: drain overflowed destinations: {res['over_capacity']}")
        ok = False
    if len(res["destinations"]) < 2:
        print("FAIL: drain convoyed onto a single destination")
        ok = False
    if res["reconstructions"]:
        print("FAIL: drain cost lineage reconstructions")
        ok = False
    print("\ndataplane smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dataplane-smoke", action="store_true")
    ap.add_argument("--drain-p2p-smoke", action="store_true")
    ap.add_argument("--headplane-smoke", action="store_true")
    ap.add_argument("--broadcast-smoke", action="store_true")
    args = ap.parse_args()
    if args.dataplane_smoke:
        raise SystemExit(smoke())
    if args.drain_p2p_smoke:
        raise SystemExit(drain_p2p_smoke())
    if args.headplane_smoke:
        raise SystemExit(headplane_smoke())
    if args.broadcast_smoke:
        raise SystemExit(broadcast_smoke())
    counts = [2, 4, 8] if args.quick else [2, 4, 8, 16, 32]
    rows = bench_shuffle(counts, obj_bytes=4 * MB)
    print_shuffle(rows)
    print_drain(drain_run())
    print_drain_plane(drain_plane_run("p2p"), drain_plane_run("relay"))
    head_counts = [64, 256] if args.quick else [64, 256, 1000]
    print_headplane(bench_headplane(head_counts),
                    wire_run(batched=False), wire_run(batched=True))
    print_broadcast(broadcast_run(), batched_move_run(), delta_spill_run())


if __name__ == "__main__":
    main()
