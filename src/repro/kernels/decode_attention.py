"""Pallas TPU decode attention: one new token against a long KV cache.

Memory-bound by design: the kernel streams the cache exactly once from HBM
(int8 cache halves the bytes; dequantization happens in VMEM), keeps the
online-softmax state in VMEM scratch, and applies the per-sequence validity
bound so continuous batching can mix sequences of different lengths.

Layout: q (B, Hq, D); k/v (B, Hkv, S, D) [bf16 or int8 + (B, Hkv, S, 1)
fp32 scales]; valid_len (B, 1) int32. Out (B, Hq, D).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, vl_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, block_k: int,
                   n_kb: int, int8: bool):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = vl_ref[0, 0]
    k_start = jk * block_k

    @pl.when(k_start < valid)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (1, d) row block
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        if int8:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = kpos < valid
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * mask
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_prev * alpha[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(jk == n_kb - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, *,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None,
                     block_k: int = 512, interpret: bool = True) -> jax.Array:
    """q (B,Hq,D); k/v (B,Hkv,S,D); valid_len (B,) -> (B,Hq,D)."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    R = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_kb = S // block_k
    int8 = k.dtype == jnp.int8
    if k_scale is None:
        k_scale = jnp.ones((B, Hkv, S, 1), jnp.float32)
    if v_scale is None:
        v_scale = jnp.ones((B, Hkv, S, 1), jnp.float32)
    vl = valid_len.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=1.0 / math.sqrt(D),
                               block_k=block_k, n_kb=n_kb, int8=int8)
    q3 = q.reshape(B, Hq, 1, D)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h // R, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h // R, j, 0)),
            pl.BlockSpec((1, 1, block_k, 1), lambda b, h, j: (b, h // R, j, 0)),
            pl.BlockSpec((1, 1, block_k, 1), lambda b, h, j: (b, h // R, j, 0)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k, v, k_scale, v_scale, vl)
    return out.reshape(B, Hq, D)
