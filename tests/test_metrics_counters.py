"""Drain-plane counters exported through the head's `metrics` op and
the K8s custom-metrics adapter (ROADMAP: the store tracked
moves_aborted / relay_fallbacks / head_relayed_bytes / replica_gc but
nothing reported them)."""
import json
import socket
import threading
import time
import urllib.request

import pytest

from test_drain_p2p import _Peer, _finish_drain
from repro.core import SyndeoCluster
from repro.core.metrics_adapter import (DEFAULT_METRICS, MetricsPoller,
                                        make_server)
from repro.core.rendezvous import FileRendezvous
from repro.core.scheduler import SchedulerConfig
from repro.core.worker import HeadServer

COUNTERS = ("syndeo_moves_aborted", "syndeo_relay_fallbacks",
            "syndeo_head_relayed_bytes", "syndeo_replica_gc")


@pytest.fixture()
def proto(tmp_path):
    cluster = SyndeoCluster(
        rendezvous=FileRendezvous(str(tmp_path)),
        scheduler_config=SchedulerConfig(enable_speculation=False,
                                         migration_timeout_s=0.4))
    server = HeadServer(cluster)
    server.attach()
    peers = {name: _Peer(cluster, server, name)
             for name in ("tcp-src", "tcp-d1", "tcp-d2")}
    ref = peers["tcp-src"].add_blob(b"\xab" * 64_000, "obj-fat")
    yield cluster, server, peers, ref
    for p in peers.values():
        p.shutdown()
    server.shutdown()
    cluster.shutdown()


def _counters(server):
    reply = server.dispatch({"op": "metrics"})
    assert reply["ok"]
    return {k: reply[k] for k in COUNTERS}


def test_metrics_op_reports_counters_as_ints(proto):
    _cluster, server, _peers, _ref = proto
    vals = _counters(server)
    assert all(isinstance(v, int) for v in vals.values())
    assert all(v == 0 for v in vals.values()), vals


def test_counters_move_during_chaos_drain(proto):
    """Partition chaos: the drain's direct push black-holes, the move
    aborts and degrades to head relay, and afterwards a client-read
    head replica is swept -- all four counters must move, and must be
    visible through the same `metrics` op the adapter polls."""
    cluster, server, peers, ref = proto
    src = peers["tcp-src"]
    before = _counters(server)

    assert server.dispatch({"op": "drain", "worker": src.name})["ok"]
    moves = src.poll().get("migrations", [])
    assert moves
    dst = moves[0]["node"]
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        dead = s.getsockname()             # bound, never accepting
        src.run_directives(moves, endpoint_override=dead)
    deadline = time.time() + 10
    while time.time() < deadline:          # relay thread lands the move
        if dst in cluster.store.locations(ref):
            break
        time.sleep(0.02)
    assert _finish_drain(cluster, server, src.name)

    after_drain = _counters(server)
    assert after_drain["syndeo_moves_aborted"] \
        > before["syndeo_moves_aborted"]
    assert after_drain["syndeo_relay_fallbacks"] \
        > before["syndeo_relay_fallbacks"]
    assert after_drain["syndeo_head_relayed_bytes"] \
        > before["syndeo_head_relayed_bytes"]

    # client read materializes a head replica; the refcount drop sweeps
    # it (release keeps the owner serving) -- replica_gc must tick
    cluster.store.add_ref(ref)
    cluster.store.get("head", ref, capability=None)
    cluster.store.mark_client_read(ref)
    cluster.store.release(ref)
    after_gc = _counters(server)
    assert after_gc["syndeo_replica_gc"] > before["syndeo_replica_gc"]


def test_default_metrics_include_drain_counters():
    for name in COUNTERS:
        assert name in DEFAULT_METRICS


def test_adapter_serves_drain_counters(tmp_path):
    """The /metrics face (flat JSON) and the custom.metrics.k8s.io
    resource path both publish the counters the poller saw."""
    poller = MetricsPoller(str(tmp_path), "c0")  # never started: inject
    poller.latest = {"ok": True, "syndeo_moves_aborted": 3,
                     "syndeo_relay_fallbacks": 1,
                     "syndeo_head_relayed_bytes": 64018,
                     "syndeo_replica_gc": 2}
    server = make_server(poller, DEFAULT_METRICS)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    port = server.server_address[1]
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=5) as resp:
            flat = json.load(resp)
        assert flat["syndeo_moves_aborted"] == 3
        assert flat["syndeo_head_relayed_bytes"] == 64018
        url = (f"http://127.0.0.1:{port}/apis/custom.metrics.k8s.io/"
               f"v1beta1/namespaces/default/pods/*/"
               f"syndeo_relay_fallbacks")
        with urllib.request.urlopen(url, timeout=5) as resp:
            payload = json.load(resp)
        assert payload["kind"] == "MetricValueList"
        assert payload["items"][0]["valueFloat"] == 1.0
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=5)
