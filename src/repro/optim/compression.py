"""Quantized ring all-reduce with error feedback (gradient compression).

A real wire-compression scheme, not an emulation: the ring reduce-scatter
and all-gather move int8 chunks (+ one fp32 scale per chunk) through
lax.ppermute, so on a real fabric each hop transfers ~1/4 of the bf16
bytes. Accumulation happens in fp32 after dequantization at every hop
(standard quantized-ring semantics); the residual between the true local
gradient and its quantized representation is fed back into the next step
(error feedback), which is what keeps SGD/Adam convergence intact.

Usage inside shard_map over the DP axis:
    g_avg, new_err = compressed_psum_mean(g, err, axis_name="data")
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compressed_psum_mean(g: jax.Array, err: jax.Array, axis_name: str
                         ) -> Tuple[jax.Array, jax.Array]:
    """Mean-reduce `g` over `axis_name` with int8 ring collectives.

    Must be called inside shard_map/pmap with `axis_name` bound. Returns
    (mean gradient, new error-feedback residual). g is flattened internally;
    the axis size must divide g.size (pad upstream if needed).
    """
    # psum of a concrete 1 constant-folds to the axis size as a python int
    # (jax.lax.axis_size was removed from the public API)
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    flat = (g.astype(F32) + err.astype(F32)).reshape(-1)
    assert flat.size % n == 0, (flat.size, n)
    chunks = flat.reshape(n, -1)

    perm = [(i, (i + 1) % n) for i in range(n)]

    # ---- ring reduce-scatter: after n-1 hops, device d owns the full sum of
    # chunk (d+1) mod n ----------------------------------------------------------
    def rs_step(s, carry):
        acc, send_q, send_scale = carry
        recv_q = jax.lax.ppermute(send_q, axis_name, perm)
        recv_scale = jax.lax.ppermute(send_scale, axis_name, perm)
        # the chunk this device must contribute to at hop s
        chunk_id = (idx - s) % n
        partial_sum = _dequant(recv_q, recv_scale) + chunks[chunk_id]
        q, sc = _quant(partial_sum)
        return (partial_sum, q, sc)

    # hop 0: every device sends its own chunk; at hop s it contributes chunk
    # (idx - s) mod n; after n-1 hops it owns the full sum of (idx+1) mod n
    q0, s0 = _quant(chunks[idx])
    carry = (chunks[idx], q0, s0)
    for s in range(1, n):
        carry = rs_step(s, carry)
    owned_sum, owned_q, owned_scale = carry
    owned_id = (idx - (n - 1)) % n

    # ---- ring all-gather of the quantized owned chunks -------------------------
    gathered_q = jnp.zeros((n,) + owned_q.shape, jnp.int8)
    gathered_s = jnp.zeros((n,), F32)
    gathered_q = gathered_q.at[owned_id].set(owned_q)
    gathered_s = gathered_s.at[owned_id].set(owned_scale)
    send_q, send_s, send_id = owned_q, owned_scale, owned_id
    for _ in range(n - 1):
        send_q = jax.lax.ppermute(send_q, axis_name, perm)
        send_s = jax.lax.ppermute(send_s, axis_name, perm)
        send_id = jax.lax.ppermute(send_id, axis_name, perm)
        gathered_q = gathered_q.at[send_id].set(send_q)
        gathered_s = gathered_s.at[send_id].set(send_s)

    total = _dequant(gathered_q, gathered_s[:, None]).reshape(flat.shape)
    mean = (total / n).reshape(g.shape).astype(g.dtype)

    # ---- error feedback: residual of the local quantized contribution ----------
    # what the ring actually carried for our local data is (approximately) the
    # quantization of (g + err); the residual re-enters next step
    q_local, s_local = _quant(flat)
    carried = _dequant(q_local, s_local)
    new_err = (flat - carried).reshape(g.shape).astype(F32)
    return mean, new_err


def make_compressed_grad_reduce(mesh, axis_name: str):
    """shard_map wrapper: reduce a replicated-per-DP-shard gradient pytree."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def reduce_tree(grads, errs):
        def one(g, e):
            fn = shard_map(
                partial(compressed_psum_mean, axis_name=axis_name),
                mesh=mesh,
                in_specs=(P(axis_name), P(axis_name)),
                out_specs=(P(axis_name), P(axis_name)),
            )
            return fn(g, e)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(errs)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return reduce_tree
