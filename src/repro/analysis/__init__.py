"""syndeo-lint: concurrency + wire-protocol static analysis.

Three AST passes over the Syndeo control plane (``src/repro/core``):

* ``locks``  -- SYN-L001 blocking I/O under a lock, SYN-L002
  lock-acquisition-order cycles.
* ``taint``  -- SYN-A001 unverified socket data reaching a store
  mutation, SYN-A002 op branches that mutate before ticket
  verification, SYN-A003 ``open_sealed()`` without a nonce cache.
* ``wire``   -- SYN-W001/W002/W003 client/handler op-frame drift.

Run as a CI gate with ``python -m repro.analysis src/repro/core``;
reviewed suppressions live in ``analysis/baseline.toml``.
"""
from __future__ import annotations

from typing import Iterable, List

from repro.analysis.locks import check_locks
from repro.analysis.model import CodeModel, Finding, build_model
from repro.analysis.taint import check_taint
from repro.analysis.wire import check_wire

__all__ = ["CodeModel", "Finding", "build_model", "check_locks",
           "check_taint", "check_wire", "run_analysis"]


def run_analysis(paths: Iterable[str]) -> List[Finding]:
    model = build_model(paths)
    findings = (check_locks(model) + check_taint(model)
                + check_wire(model))
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return findings
