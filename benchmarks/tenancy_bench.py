"""Multi-tenant fairness benchmark: fair-share (DRF) vs the FIFO baseline.

Two equal-weight tenants contend for one fixed-size gang allocation on the
REAL scheduler code (the simulation backend drives the same Scheduler /
GlobalObjectStore as the threaded backend):

  * "steady"  -- a constant arrival stream (an online-serving tenant),
  * "bursty"  -- one large batch dropped mid-stream (a batch-training
                 tenant), deliberately big enough to starve the steady
                 tenant under arrival-order dispatch.

Reported per policy ("fair" = per-tenant queues + weighted dominant-share
picker, "fifo" = the seed's single global arrival-order queue):

  * dominant-share gap -- mean |share(steady) - share(bursty)| sampled at
    every scheduler tick *while both tenants have backlog* (equal weights
    under contention should see equal dominant shares; the gap is the
    fairness error),
  * p50 / p99 task sojourn per tenant (virtual seconds from arrival to
    finish) and per-tenant makespan.

The fair-share scheduler must keep the dominant-share gap under
FAIR_GAP_BOUND while FIFO starves the steady tenant (gap near 1, steady
p99 blowing up). `--tenancy-smoke` runs a small instance and enforces
exactly that -- it is the CI gate next to `--drain-smoke`.

Run:  PYTHONPATH=src python benchmarks/tenancy_bench.py [--quick]
      PYTHONPATH=src python benchmarks/tenancy_bench.py --tenancy-smoke
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Tuple

from repro.core import SchedulerConfig, SimCluster, SimCostModel, TaskSpec
from repro.core.task_graph import TaskState

#: fairness bound the fair-share scheduler must hold (mean weighted
#: dominant-share gap between equal-weight tenants while both are backlogged)
FAIR_GAP_BOUND = 0.15
#: the FIFO baseline must exhibit at least this much unfairness (otherwise
#: the scenario is not actually contended and the comparison is vacuous)
FIFO_GAP_FLOOR = 0.5
#: FIFO must inflate the steady tenant's p99 sojourn by at least this factor
STARVATION_FACTOR = 1.5


def _quantile(sorted_xs: List[float], q: float) -> float:
    if not sorted_xs:
        return 0.0
    i = min(len(sorted_xs) - 1, int(q * len(sorted_xs)))
    return sorted_xs[i]


def run_contention(policy: str, n_workers: int, steady_n: int,
                   steady_every_s: float, burst_n: int, burst_at_s: float,
                   task_s: float = 0.5, seed: int = 1) -> Dict[str, object]:
    """One bursty-vs-steady contention run; returns fairness metrics."""
    cost = SimCostModel(task_time_s=lambda s: task_s,
                        result_bytes=lambda s: 100.0, jitter=0.0)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9,
                                           dispatch_policy=policy), seed=seed)
    sim.add_workers(n_workers)
    sim.register_tenant("steady", weight=1.0)
    sim.register_tenant("bursty", weight=1.0)
    streams = {
        "steady": [(steady_every_s * i, TaskSpec(fn=None, group="steady"))
                   for i in range(steady_n)],
        "bursty": [(burst_at_s, TaskSpec(fn=None, group="bursty"))
                   for _ in range(burst_n)],
    }
    gaps: List[float] = []

    def on_tick(now: float):
        backlog = sim.scheduler.backlog_by_tenant()
        if backlog.get("steady", 0) and backlog.get("bursty", 0):
            shares = sim.scheduler.tenant_shares()
            gaps.append(abs(shares.get("steady", 0.0)
                            - shares.get("bursty", 0.0)))

    placed = sim.run_tenant_scenario(streams, tick_every=0.1,
                                     on_tick=on_tick)
    row: Dict[str, object] = {
        "policy": policy,
        "dominant_share_gap": sum(gaps) / len(gaps) if gaps else 0.0,
        "contended_samples": len(gaps),
    }
    for tenant, pairs in placed.items():
        sojourns = sorted(
            sim.scheduler.graph.tasks[tid].finished_at - t
            for t, tid in pairs
            if sim.scheduler.graph.tasks[tid].state == TaskState.FINISHED)
        done = len(sojourns)
        row[f"{tenant}_done"] = done
        row[f"{tenant}_p50_s"] = _quantile(sojourns, 0.50)
        row[f"{tenant}_p99_s"] = _quantile(sojourns, 0.99)
        row[f"{tenant}_makespan_s"] = (
            max((sim.scheduler.graph.tasks[tid].finished_at or 0.0)
                for _, tid in pairs) - min(t for t, _ in pairs)
            if pairs else 0.0)
    return row


def bench(quick: bool) -> Tuple[Dict[str, object], Dict[str, object]]:
    kw = (dict(n_workers=8, steady_n=200, steady_every_s=0.1,
               burst_n=150, burst_at_s=2.0) if quick else
          dict(n_workers=16, steady_n=600, steady_every_s=0.05,
               burst_n=600, burst_at_s=4.0))
    return (run_contention("fair", **kw), run_contention("fifo", **kw))


def report(fair: Dict[str, object], fifo: Dict[str, object]) -> bool:
    cols = ["policy", "dominant_share_gap", "contended_samples",
            "steady_p50_s", "steady_p99_s", "steady_makespan_s",
            "bursty_p50_s", "bursty_p99_s", "bursty_makespan_s"]
    print("=== two equal-weight tenants, fixed gang: fair-share vs FIFO "
          "(virtual time) ===")
    print("".join(f"{c:>20s}" for c in cols))
    for row in (fair, fifo):
        print("".join(f"{row[c]:>20.3f}" if isinstance(row[c], float)
                      else f"{row[c]:>20}" for c in cols))

    ok = True
    if fair["contended_samples"] == 0 or fifo["contended_samples"] == 0:
        print("\nFAIL: scenario never contended -- comparison is vacuous")
        ok = False
    if fair["dominant_share_gap"] >= FAIR_GAP_BOUND:
        print(f"\nFAIL: fair-share dominant-share gap "
              f"{fair['dominant_share_gap']:.3f} >= {FAIR_GAP_BOUND}")
        ok = False
    if fifo["dominant_share_gap"] <= FIFO_GAP_FLOOR:
        print(f"\nFAIL: FIFO baseline gap {fifo['dominant_share_gap']:.3f} "
              f"<= {FIFO_GAP_FLOOR} -- burst did not starve the steady "
              f"tenant, scenario too small")
        ok = False
    if fifo["steady_p99_s"] <= STARVATION_FACTOR * fair["steady_p99_s"]:
        print(f"\nFAIL: FIFO steady p99 {fifo['steady_p99_s']:.2f}s not "
              f">= {STARVATION_FACTOR}x fair-share "
              f"{fair['steady_p99_s']:.2f}s")
        ok = False
    for row in (fair, fifo):
        for tenant in ("steady", "bursty"):
            if not row[f"{tenant}_done"]:
                print(f"\nFAIL: {row['policy']} finished no "
                      f"{tenant} tasks")
                ok = False
    if ok:
        print(f"\nfair-share gap {fair['dominant_share_gap']:.3f} < "
              f"{FAIR_GAP_BOUND}; FIFO gap "
              f"{fifo['dominant_share_gap']:.3f}; steady-tenant p99 "
              f"{fifo['steady_p99_s']:.2f}s (FIFO) -> "
              f"{fair['steady_p99_s']:.2f}s (fair)")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes for CI smoke")
    ap.add_argument("--tenancy-smoke", action="store_true",
                    help="small instance + hard fairness assertions "
                         "(the CI gate)")
    args = ap.parse_args()
    fair, fifo = bench(quick=args.quick or args.tenancy_smoke)
    ok = report(fair, fifo)
    print("\nPASS" if ok else "\nFAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
