"""The dynamic (Ray-style) scheduler that Syndeo hosts *inside* the static
gang allocation -- the paper's scheduler-inside-a-scheduler.

Event-driven state machine, independent of the time source: the local
backend drives it with threads + wall clock, the simulation backend drives
it with a virtual clock (same code paths -- the paper-table benchmarks
exercise exactly this logic).

Features:
  * dependency-driven dispatch (tasks start when data + resource deps met),
  * locality-aware placement (prefer workers already holding the deps),
  * straggler mitigation: speculative re-execution past a runtime quantile,
  * retry with lineage reconstruction of lost objects on worker failure,
  * placement groups (STRICT_SPREAD / PACK) for gang-scheduled jobs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.object_store import GlobalObjectStore, NodeStore, ObjectRef
from repro.core.task_graph import Task, TaskGraph, TaskSpec, TaskState


@dataclass
class WorkerInfo:
    id: str
    resources: Dict[str, float]
    available: Dict[str, float] = field(default_factory=dict)
    alive: bool = True
    last_heartbeat: float = 0.0
    running: set = field(default_factory=set)

    def __post_init__(self):
        if not self.available:
            self.available = dict(self.resources)

    def fits(self, req: Dict[str, float]) -> bool:
        return all(self.available.get(k, 0.0) >= v for k, v in req.items())

    def acquire(self, req: Dict[str, float]):
        for k, v in req.items():
            self.available[k] = self.available.get(k, 0.0) - v

    def release(self, req: Dict[str, float]):
        for k, v in req.items():
            self.available[k] = self.available.get(k, 0.0) + v


@dataclass
class SchedulerConfig:
    speculation_factor: float = 2.0      # speculate past factor x group median
    speculation_min_samples: int = 5
    heartbeat_timeout: float = 10.0
    locality_weight: float = 1.0         # bytes-on-node score weight
    enable_speculation: bool = True


class Scheduler:
    """Head-node scheduler. All mutation happens through the public event
    methods; `launch_fn(task, worker_id)` is injected by the backend."""

    def __init__(self, store: GlobalObjectStore,
                 launch_fn: Callable[[Task, str], None],
                 cancel_fn: Optional[Callable[[Task, str], None]] = None,
                 config: SchedulerConfig = SchedulerConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.store = store
        self.graph = TaskGraph()
        self.workers: Dict[str, WorkerInfo] = {}
        self.launch_fn = launch_fn
        self.cancel_fn = cancel_fn or (lambda t, w: None)
        self.cfg = config
        self.clock = clock
        self._group_runtimes: Dict[str, List[float]] = {}
        self._placement_bindings: Dict[str, Dict[int, str]] = {}
        self.stats = {"launched": 0, "finished": 0, "failed": 0, "retried": 0,
                      "speculative": 0, "reconstructed": 0, "cancelled": 0}

    # -- membership ----------------------------------------------------------

    def add_worker(self, worker: WorkerInfo):
        worker.last_heartbeat = self.clock()
        self.workers[worker.id] = worker
        self.schedule()

    def remove_worker(self, worker_id: str):
        self.on_worker_failed(worker_id, reason="removed")

    def heartbeat(self, worker_id: str):
        w = self.workers.get(worker_id)
        if w:
            w.last_heartbeat = self.clock()

    def check_liveness(self):
        now = self.clock()
        for w in list(self.workers.values()):
            if w.alive and now - w.last_heartbeat > self.cfg.heartbeat_timeout:
                self.on_worker_failed(w.id, reason="heartbeat timeout")

    # -- submission ----------------------------------------------------------

    def submit(self, spec: TaskSpec, deps: Optional[List[ObjectRef]] = None) -> Task:
        task = Task(spec=spec, deps=list(deps or []))
        for d in task.deps:
            self.store.add_ref(d)
            if self.store.locations(d):
                # dep already materialized (e.g. cluster.put artifacts)
                self.graph.mark_available(d.id)
        self.graph.add(task)
        self.schedule()
        return task

    # -- core scheduling pass --------------------------------------------------

    def _locality_score(self, task: Task, worker: WorkerInfo) -> float:
        score = 0.0
        for d in task.deps:
            if worker.id in self.store.locations(d):
                score += self.store.size_of(d)
        return score * self.cfg.locality_weight

    def _pick_worker(self, task: Task) -> Optional[WorkerInfo]:
        req = task.spec.resources
        if task.spec.placement_group:
            bound = self._placement_bindings.get(task.spec.placement_group, {})
            wid = bound.get(task.spec.bundle_index)
            if wid is not None:
                w = self.workers.get(wid)
                return w if (w and w.alive and w.fits(req)) else None
        best, best_key = None, None
        for w in self.workers.values():
            if not w.alive or not w.fits(req):
                continue
            load = sum(w.resources.values()) - sum(w.available.values())
            key = (self._locality_score(task, w), -load)
            if best_key is None or key > best_key:
                best, best_key = w, key
        return best

    def schedule(self):
        for task in sorted(self.graph.ready_tasks(),
                           key=lambda t: t.submitted_at):
            w = self._pick_worker(task)
            if w is None:
                continue
            task.state = TaskState.RUNNING
            task.worker = w.id
            task.started_at = self.clock()
            task.attempts += 1
            w.acquire(task.spec.resources)
            w.running.add(task.id)
            self.stats["launched"] += 1
            self.launch_fn(task, w.id)

    # -- completion events -----------------------------------------------------

    def on_task_finished(self, task_id: str, output: ObjectRef):
        task = self.graph.tasks.get(task_id)
        if task is None or task.state not in (TaskState.RUNNING,):
            return
        task.state = TaskState.FINISHED
        task.finished_at = self.clock()
        task.output = output
        self._release(task)
        self.stats["finished"] += 1
        rt = task.runtime
        if rt is not None:
            self._group_runtimes.setdefault(task.spec.group, []).append(rt)
        # cancel the twin (speculation): first finisher wins
        twin_id = task.speculative_of
        twins = [t for t in self.graph.tasks.values()
                 if t.speculative_of == task.id or (twin_id and t.id == twin_id)]
        for t in twins:
            if t.state == TaskState.RUNNING:
                t.state = TaskState.CANCELLED
                self._release(t)
                self.stats["cancelled"] += 1
                self.cancel_fn(t, t.worker)
        for ready in self.graph.object_available(output):
            pass
        self.schedule()

    def on_task_failed(self, task_id: str, error: str):
        task = self.graph.tasks.get(task_id)
        if task is None or task.state != TaskState.RUNNING:
            return
        self._release(task)
        self.stats["failed"] += 1
        if task.attempts <= task.spec.max_retries:
            task.state = TaskState.READY if self._deps_live(task) else TaskState.PENDING
            task.error = error
            self.stats["retried"] += 1
            self._reconstruct_missing(task)
        else:
            task.state = TaskState.FAILED
            task.error = error
        self.schedule()

    def _release(self, task: Task):
        w = self.workers.get(task.worker or "")
        if w and task.id in w.running:
            w.running.discard(task.id)
            w.release(task.spec.resources)

    # -- failure handling --------------------------------------------------------

    def on_worker_failed(self, worker_id: str, reason: str = "failure"):
        w = self.workers.get(worker_id)
        if w is None:
            return
        w.alive = False
        lost_objects = self.store.unregister_node(worker_id)
        for oid in lost_objects:
            self.graph.object_lost(oid)
        # requeue running tasks
        for tid in list(w.running):
            task = self.graph.tasks[tid]
            self._release(task)
            if task.attempts <= task.spec.max_retries:
                task.state = TaskState.READY if self._deps_live(task) else TaskState.PENDING
                self.stats["retried"] += 1
                self._reconstruct_missing(task)
            else:
                task.state = TaskState.FAILED
                task.error = f"worker {worker_id} {reason}"
        del self.workers[worker_id]
        self.schedule()

    def _deps_live(self, task: Task) -> bool:
        return all(self.store.locations(d) for d in task.deps)

    def _reconstruct_missing(self, task: Task):
        """Lineage reconstruction: re-submit producers of lost deps."""
        for d in task.deps:
            if self.store.locations(d):
                continue
            producer_id = self.store.lineage(d) or d.producer_task
            producer = self.graph.tasks.get(producer_id or "")
            if producer is None:
                continue
            if producer.state in (TaskState.FINISHED, TaskState.FAILED,
                                  TaskState.CANCELLED):
                producer.state = TaskState.READY if self._deps_live(producer) \
                    else TaskState.PENDING
                producer.attempts = 0
                producer.output = None
                self.store.note_reconstruction()
                self.stats["reconstructed"] += 1
                self._reconstruct_missing(producer)  # recursive lineage

    # -- straggler mitigation ------------------------------------------------------

    def check_stragglers(self):
        if not self.cfg.enable_speculation:
            return
        now = self.clock()
        for task in self.graph.running_tasks():
            if task.speculated or task.speculative_of:
                continue
            hist = self._group_runtimes.get(task.spec.group, [])
            if len(hist) < self.cfg.speculation_min_samples:
                continue
            median = sorted(hist)[len(hist) // 2]
            started = task.started_at if task.started_at is not None else now
            if (now - started) > self.cfg.speculation_factor * median:
                twin = Task(spec=task.spec, deps=list(task.deps),
                            speculative_of=task.id)
                task.speculated = True
                self.graph.add(twin)
                self.stats["speculative"] += 1
        self.schedule()

    # -- placement groups -----------------------------------------------------------

    def create_placement_group(self, name: str,
                               bundles: List[Dict[str, float]],
                               strategy: str = "SPREAD") -> bool:
        """Reserve resources for a gang; returns False if unsatisfiable."""
        binding: Dict[int, str] = {}
        used: Dict[str, Dict[str, float]] = {}
        workers = [w for w in self.workers.values() if w.alive]
        for i, bundle in enumerate(bundles):
            placed = False
            for w in sorted(workers, key=lambda w: len(w.running)):
                if strategy == "STRICT_SPREAD" and w.id in binding.values():
                    continue
                tentative = used.setdefault(w.id, {})
                avail = {k: w.available.get(k, 0.0) - tentative.get(k, 0.0)
                         for k in bundle}
                if all(avail[k] >= v for k, v in bundle.items()):
                    for k, v in bundle.items():
                        tentative[k] = tentative.get(k, 0.0) + v
                    binding[i] = w.id
                    placed = True
                    break
            if not placed:
                return False
        self._placement_bindings[name] = binding
        return True

    def placement_binding(self, name: str) -> Dict[int, str]:
        return dict(self._placement_bindings.get(name, {}))
