"""Architecture config registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, param_count, active_param_count
from repro.configs.shapes import SHAPES, ShapeConfig, applicable

_MODULES = {
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "arctic-480b": "arctic_480b",
    "zamba2-2.7b": "zamba2_2p7b",
    "whisper-tiny": "whisper_tiny",
    "xlstm-350m": "xlstm_350m",
    "granite-8b": "granite_8b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llama3-8b": "llama3_8b",
    "stablelm-12b": "stablelm_12b",
    "internvl2-76b": "internvl2_76b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "get_config", "all_configs", "ModelConfig", "ShapeConfig",
    "SHAPES", "applicable", "param_count", "active_param_count",
]
