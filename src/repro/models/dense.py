"""Dense (llama-family) decoder LM, plus the shared transformer block used by
the MoE / VLM / whisper-decoder families.

Layers are *scanned* (stacked params, `jax.lax.scan`) so the lowered HLO is
one block body regardless of depth -- this keeps 512-device dry-run compiles
fast and is also what production TPU stacks (MaxText et al.) do.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe_layer, moe_ffn
from repro.sharding.axes import constrain

F32 = jnp.float32


# ----------------------------------------------------------------------------
# Parameter init
# ----------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype):
    ka, km, kn = jax.random.split(key, 3)
    p: Dict[str, Any] = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, cfg.qkv_bias, dtype,
                                 cfg.pad_heads_to, cfg.pad_kv_heads_to),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe_layer(km, cfg, dtype)
    else:
        std = cfg.d_model ** -0.5
        k1, k2, k3 = jax.random.split(km, 3)
        p["mlp"] = {
            "w1": (jax.random.normal(k1, (cfg.d_model, cfg.d_ff)) * std).astype(dtype),
            "w3": (jax.random.normal(k2, (cfg.d_model, cfg.d_ff)) * std).astype(dtype),
            "w2": (jax.random.normal(k3, (cfg.d_ff, cfg.d_model)) * std).astype(dtype),
        }
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    return {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype,
                                  cfg.tie_embeddings, cfg.padded_vocab),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------

def block_fwd(p, x, positions, cfg: ModelConfig, *, n_groups: int = 1,
              window: Optional[int] = None):
    """Training/prefill block: full-sequence attention + FFN."""
    h, _ = L.attention(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                       positions, cfg, causal=True, window=window)
    x = x + h
    aux = jnp.zeros((), F32)
    xn = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_ffn(p["moe"], xn, cfg, n_groups)
    else:
        y = L.swiglu(xn, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
    # "seq" is unmapped by default; binding it to the model axis turns the
    # per-block TP all-reduces into reduce-scatter+all-gather pairs
    # (Megatron-style sequence parallelism; §Perf it2)
    return constrain(x + y, "batch", "seq", None), aux


def backbone_fwd(params, x, positions, cfg: ModelConfig, *, n_groups: int = 1,
                 window: Optional[int] = None, remat: bool = True):
    """Scan the block stack over stacked layer params. x: (B, T, d)."""
    def body(carry, lp):
        y, aux = block_fwd(lp, carry, positions, cfg, n_groups=n_groups,
                           window=window)
        return y, aux

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.sum(auxs)


def lm_loss(params, batch, cfg: ModelConfig, *, n_groups: int = 1):
    tokens, targets = batch["tokens"], batch["targets"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = L.embed(params["embed"], tokens)
    x = _inject_frontend(params, batch, x, cfg)
    x, aux = backbone_fwd(params, x, positions, cfg, n_groups=n_groups)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    mask = batch.get("loss_mask")
    loss = L.softmax_xent(logits, targets, mask)
    return loss + aux, {"xent": loss, "aux": aux}


def _inject_frontend(params, batch, x, cfg: ModelConfig):
    """VLM stub frontend: precomputed patch embeddings replace the first
    n_patches token embeddings (the ViT itself is out of scope per spec)."""
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        np_ = pe.shape[1]
        x = jnp.concatenate([pe, x[:, np_:]], axis=1)
    return x


# ----------------------------------------------------------------------------
# KV cache + serving
# ----------------------------------------------------------------------------

def _kv_cache_dtype(cfg: ModelConfig):
    return jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.dtype(cfg.param_dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    kd = _kv_cache_dtype(cfg)
    shape = (cfg.n_layers, batch, max_len, cfg.cache_kv_heads, hd)
    cache = {
        "k": jnp.zeros(shape, kd),
        "v": jnp.zeros(shape, kd),
    }
    if cfg.kv_cache_dtype == "int8":
        cache["k_scale"] = jnp.zeros(shape[:-1] + (1,), F32)
        cache["v_scale"] = jnp.zeros(shape[:-1] + (1,), F32)
    return cache


def cache_pspec_tree(cfg: ModelConfig, cache):
    """Logical specs for the cache (layers, batch, seq, kv_heads, hd)."""
    spec = ("__layer", "batch", None, "model", None)
    return jax.tree.map(lambda _: spec, cache,
                        is_leaf=lambda x: not isinstance(x, dict))


def _quantize_kv(x):
    """Per (token, head) symmetric int8 quantization."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _store_kv(cfg, ck, cv, ck_s, cv_s, k, v, pos):
    """Scatter this step's (k, v) (B, S, H, D) into cache at positions pos (B,)."""
    B = k.shape[0]
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
    else:
        kq, vq, ks, vs = k, v, None, None

    bidx = jnp.arange(B)[:, None]
    S = k.shape[1]
    tidx = pos[:, None] + jnp.arange(S)[None, :]
    ck = ck.at[bidx, tidx].set(kq.astype(ck.dtype), mode="drop")
    cv = cv.at[bidx, tidx].set(vq.astype(cv.dtype), mode="drop")
    if ck_s is not None:
        ck_s = ck_s.at[bidx, tidx].set(ks, mode="drop")
        cv_s = cv_s.at[bidx, tidx].set(vs, mode="drop")
    return ck, cv, ck_s, cv_s


def block_decode(p, x, cache_slices, pos, cfg: ModelConfig, *, n_groups: int = 1,
                 window: Optional[int] = None):
    """One decode step through one block. x: (B, 1, d); pos: (B,) current len."""
    ck, cv, ck_s, cv_s = cache_slices
    xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    hd = cfg.resolved_head_dim
    B, T, _ = x.shape

    q = jnp.einsum("btd,dq->btq", xn, p["attn"]["wq"])
    k = jnp.einsum("btd,dk->btk", xn, p["attn"]["wk"])
    v = jnp.einsum("btd,dk->btk", xn, p["attn"]["wv"])
    if "bq" in p["attn"]:
        q, k, v = q + p["attn"]["bq"], k + p["attn"]["bk"], v + p["attn"]["bv"]
    q = q.reshape(B, T, cfg.eff_q_heads, hd)
    k = k.reshape(B, T, cfg.eff_kv_heads, hd)
    v = v.reshape(B, T, cfg.eff_kv_heads, hd)
    positions = pos[:, None] + jnp.arange(T)[None, :]
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if cfg.kv_replication > 1:
        k = jnp.repeat(k, cfg.kv_replication, axis=2)
        v = jnp.repeat(v, cfg.kv_replication, axis=2)

    ck, cv, ck_s, cv_s = _store_kv(cfg, ck, cv, ck_s, cv_s, k, v, pos)

    kc, vc = ck, cv
    kv_scale = None
    if cfg.kv_cache_dtype == "int8":
        kv_scale = ck_s  # k and v share the attend path; v scale applied below
    valid = pos + T
    out = _decode_attend(q, kc, vc, ck_s, cv_s, valid, cfg, window)
    out = out.reshape(B, T, cfg.eff_q_heads * hd)
    x = x + jnp.einsum("btq,qd->btd", out, p["attn"]["wo"])

    xn = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = moe_ffn(p["moe"], xn, cfg, n_groups)
    else:
        y = L.swiglu(xn, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
    return x + y, (ck, cv, ck_s, cv_s)


def _decode_attend(q, ck, cv, ck_s, cv_s, valid, cfg, window):
    """Attention of q (B, 1, Hq, hd) over the full cache buffer with a traced
    validity bound (and dequantization for int8 caches)."""
    return L.flash_attention_ref(
        q, ck, cv, causal=False, window=window,
        valid_len=valid, kv_scale=ck_s, v_scale=cv_s,
        block_q=1, block_k=min(L.DECODE_BLOCK_K, ck.shape[1]))


# direct-indexed decode: attend straight into the stacked (L,B,S,H,D) cache.
# REFUTED as an XLA-level optimization (EXPERIMENTS.md §Perf qwen it3): the
# traced-index scatter breaks while-carry aliasing and the cache gets copied
# per layer. Kept selectable for the record; default off. The production
# answer is the Pallas decode kernel (kernels/decode_attention.py), which
# streams the cache exactly once by construction.
DIRECT_CACHE_DECODE = False


def _decode_attend_5d(q, ck_all, cv_all, cks_all, cvs_all, li, valid,
                      block_k: int):
    """Online-softmax decode attention slicing blocks from the 5D cache.

    q: (B, Hc, R, hd) folded GQA; ck_all/cv_all: (L, B, S, Hc, hd);
    scales (L, B, S, Hc, 1) or None; li: traced layer index; valid: (B,).
    """
    Lc, B, S, Hc, hd = ck_all.shape
    R = q.shape[2]
    block_k = min(block_k, S)
    nk = S // block_k
    scale = 1.0 / (hd ** 0.5)
    F32 = jnp.float32

    def slice5(a, j, width):
        s = jax.lax.dynamic_slice(
            a, (li, 0, j * block_k, 0, 0), (1, B, block_k, Hc, width))
        return s[0]

    def body(carry, j):
        acc, m, l = carry
        kb = slice5(ck_all, j, hd)
        vb = slice5(cv_all, j, hd)
        if cks_all is not None:
            kb = kb.astype(F32) * slice5(cks_all, j, 1)
            vb = vb.astype(F32) * slice5(cvs_all, j, 1)
        s = jnp.einsum("bhrd,bkhd->bhrk", q.astype(F32), kb.astype(F32)) * scale
        kpos = j * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < valid[:, None]              # (B, bk)
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None]) * mask[:, None, None]
        l_new = l * alpha + jnp.sum(p, -1)
        acc_new = acc * alpha[..., None] + \
            jnp.einsum("bhrk,bkhd->bhrd", p, vb.astype(F32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hc, R, hd), F32)
    m0 = jnp.full((B, Hc, R), -1e30, F32)
    l0 = jnp.zeros((B, Hc, R), F32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.astype(ck_all.dtype if ck_all.dtype != jnp.int8 else jnp.bfloat16)


def block_decode_direct(p, x, caches, li, pos, cfg: ModelConfig, *,
                        n_groups: int = 1):
    """block_decode with in-place 5D cache writes + direct-indexed attention."""
    ck_all, cv_all, cks_all, cvs_all = caches
    xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    hd = cfg.resolved_head_dim
    B, T, _ = x.shape

    q = jnp.einsum("btd,dq->btq", xn, p["attn"]["wq"])
    k = jnp.einsum("btd,dk->btk", xn, p["attn"]["wk"])
    v = jnp.einsum("btd,dk->btk", xn, p["attn"]["wv"])
    if "bq" in p["attn"]:
        q, k, v = q + p["attn"]["bq"], k + p["attn"]["bk"], v + p["attn"]["bv"]
    q = q.reshape(B, T, cfg.eff_q_heads, hd)
    k = k.reshape(B, T, cfg.eff_kv_heads, hd)
    v = v.reshape(B, T, cfg.eff_kv_heads, hd)
    positions = pos[:, None] + jnp.arange(T)[None, :]
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if cfg.kv_replication > 1:
        k = jnp.repeat(k, cfg.kv_replication, axis=2)
        v = jnp.repeat(v, cfg.kv_replication, axis=2)

    bidx = jnp.arange(B)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        cks_all = cks_all.at[li, bidx, pos].set(ks[:, 0], mode="drop")
        cvs_all = cvs_all.at[li, bidx, pos].set(vs[:, 0], mode="drop")
    else:
        kq, vq = k, v
    ck_all = ck_all.at[li, bidx, pos].set(kq[:, 0].astype(ck_all.dtype),
                                          mode="drop")
    cv_all = cv_all.at[li, bidx, pos].set(vq[:, 0].astype(cv_all.dtype),
                                          mode="drop")

    Hc = cfg.cache_kv_heads
    R = cfg.eff_q_heads // Hc
    qf = q.reshape(B, Hc, R, hd)
    out = _decode_attend_5d(qf, ck_all, cv_all, cks_all, cvs_all, li,
                            pos + T, block_k=L.DECODE_BLOCK_K)
    out = out.reshape(B, T, cfg.eff_q_heads * hd).astype(x.dtype)
    x = x + jnp.einsum("btq,qd->btd", out, p["attn"]["wo"])

    xn = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = moe_ffn(p["moe"], xn, cfg, n_groups)
    else:
        y = L.swiglu(xn, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
    return x + y, (ck_all, cv_all, cks_all, cvs_all)


def lm_decode_step(params, cache, batch, cfg: ModelConfig, *, n_groups: int = 1,
                   window: Optional[int] = None):
    """One-token decode across the whole stack.

    The cache rides in the scan *carry* and is updated in place per layer via
    dynamic-update-slice, so XLA aliases one buffer through the loop (the
    xs->ys formulation double-buffers the multi-TB cache)."""
    tokens, pos = batch["tokens"], batch["positions"]
    x = L.embed(params["embed"], tokens)

    has_scale = "k_scale" in cache
    zero = jnp.zeros((), F32)

    def body(carry, lp):
        x_c, ck_all, cv_all, cks_all, cvs_all, li = carry
        if DIRECT_CACHE_DECODE and window is None:
            caches = (ck_all, cv_all,
                      cks_all if has_scale else None,
                      cvs_all if has_scale else None)
            y, (ck_all, cv_all, cks2, cvs2) = block_decode_direct(
                lp, x_c, caches, li, pos, cfg, n_groups=n_groups)
            if has_scale:
                cks_all, cvs_all = cks2, cvs2
            return (y, ck_all, cv_all, cks_all, cvs_all, li + 1), None
        take = lambda a: jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False)
        put = lambda a, s: jax.lax.dynamic_update_index_in_dim(a, s, li, 0)
        slices = (take(ck_all), take(cv_all),
                  take(cks_all) if has_scale else None,
                  take(cvs_all) if has_scale else None)
        y, (ck, cv, cks2, cvs2) = block_decode(lp, x_c, slices, pos, cfg,
                                               n_groups=n_groups, window=window)
        ck_all = put(ck_all, ck)
        cv_all = put(cv_all, cv)
        if has_scale:
            cks_all = put(cks_all, cks2)
            cvs_all = put(cvs_all, cvs2)
        return (y, ck_all, cv_all, cks_all, cvs_all, li + 1), None

    carry0 = (x, cache["k"], cache["v"],
              cache.get("k_scale", zero), cache.get("v_scale", zero),
              jnp.zeros((), jnp.int32))
    (x, nk, nv, nks, nvs, _), _ = jax.lax.scan(body, carry0, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    new_cache = {"k": nk, "v": nv}
    if has_scale:
        new_cache["k_scale"], new_cache["v_scale"] = nks, nvs
    return logits, new_cache


def lm_prefill(params, batch, cfg: ModelConfig, *, n_groups: int = 1,
               window: Optional[int] = None):
    """Prefill: full forward that also materializes the KV cache.

    Returns (last-token logits, cache). Cache buffers sized to seq_len.
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = L.embed(params["embed"], tokens)
    x = _inject_frontend(params, batch, x, cfg)

    hd = cfg.resolved_head_dim
    int8 = cfg.kv_cache_dtype == "int8"

    def body(carry, lp):
        xc = carry
        xn = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        h, kv = L.attention(lp["attn"], xn, positions, cfg, causal=True,
                            window=window)
        xc = xc + h
        xn = L.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_ffn(lp["moe"], xn, cfg, n_groups)
        else:
            y = L.swiglu(xn, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
        xc = xc + y
        k, v = kv
        if cfg.kv_replication > 1:
            k = jnp.repeat(k, cfg.kv_replication, axis=2)
            v = jnp.repeat(v, cfg.kv_replication, axis=2)
        if int8:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            return xc, (kq, vq, ks, vs)
        return xc, (k, v)

    x, kvs = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:, :], cfg.vocab_size)
    if int8:
        k, v, ks, vs = kvs
        cache = {"k": k, "v": v, "k_scale": ks, "v_scale": vs}
    else:
        k, v = kvs
        cache = {"k": k, "v": v}
    return logits, cache
