"""Mamba2 (SSD) blocks + the zamba2-style hybrid model.

Training/prefill uses the chunked SSD algorithm (intra-chunk quasi-attention
+ inter-chunk state recurrence, scan over chunks) so lowered memory is linear
in T and compute is O(T * chunk). Decode is the O(1) recurrent update.

zamba2: a backbone of mamba2 blocks with one *shared* attention+FFN block
applied every `attn_every` layers (parameters shared across applications,
per-application KV caches). Long-context mode uses a sliding window on the
attention block => the whole arch is sub-quadratic (long_500k runs).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.axes import constrain

F32 = jnp.float32


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads, s.head_dim, s.n_groups, s.d_state


# ----------------------------------------------------------------------------
# Mamba2 block
# ----------------------------------------------------------------------------

def init_mamba_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in, H, P, G, N = _dims(cfg)
    K = cfg.ssm.conv_dim
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    conv_ch = d_in + 2 * G * N
    return {
        "ln": jnp.ones((d,), dtype),
        "w_zx": (jax.random.normal(ks[0], (d, 2 * d_in)) * std).astype(dtype),
        "w_bc": (jax.random.normal(ks[1], (d, 2 * G * N)) * std).astype(dtype),
        "w_dt": (jax.random.normal(ks[2], (d, H)) * std).astype(dtype),
        "dt_bias": jnp.zeros((H,), F32),
        "A_log": jnp.zeros((H,), F32),
        "D": jnp.ones((H,), F32),
        "conv_w": (jax.random.normal(ks[3], (K, conv_ch)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm_w": jnp.ones((d_in,), dtype),
        "w_out": (jax.random.normal(ks[4], (d_in, d)) * (d_in ** -0.5)).astype(dtype),
    }


def _causal_conv(xbc, w, b):
    """xbc: (B, T, C); depthwise causal conv, width K."""
    K = w.shape[0]
    pads = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return out + b


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x:  (B, T, H, P)   dt: (B, T, H)   A: (H,) (negative)
    Bm/Cm: (B, T, G, N) -> broadcast to heads
    returns y: (B, T, H, P), final state (B, H, P, N)
    """
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = T // chunk
    assert T % chunk == 0, (T, chunk)

    xr = x.reshape(Bsz, nc, chunk, H, P).astype(F32)
    dtr = dt.reshape(Bsz, nc, chunk, H).astype(F32)
    Br = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, axis=3).astype(F32)
    Cr = jnp.repeat(Cm.reshape(Bsz, nc, chunk, G, N), rep, axis=3).astype(F32)

    dA = dtr * A  # (B, nc, Q, H), negative
    la = jnp.cumsum(dA, axis=2)              # within-chunk log decay
    la_end = la[:, :, -1]                    # (B, nc, H)

    # intra-chunk: scores[t,s] = (C_t . B_s) * exp(la_t - la_s) * dt_s, s<=t
    scores = jnp.einsum("bcqhn,bcshn->bchqs", Cr, Br)
    # decay: (B,nc,H,Q,S) = exp(la[...,q,h] - la[...,s,h])
    laq = la.transpose(0, 1, 3, 2)           # (B, nc, H, Q)
    decay = jnp.exp(jnp.clip(laq[..., :, None] - laq[..., None, :], -60.0, 0.0))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    w_intra = jnp.where(mask, scores * decay, 0.0) * dtr.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", w_intra, xr)

    # per-chunk end states: S_c = sum_s exp(la_end - la_s) dt_s (B_s x x_s)
    w_state = jnp.exp(jnp.clip(la_end[:, :, None, :] - la, -60.0, 0.0)) * dtr  # (B,nc,Q,H)
    S_c = jnp.einsum("bcsh,bcshn,bcshp->bchpn", w_state, Br, xr)

    def scan_body(S, inputs):
        Cc, lac, la_end_c, Sc = inputs
        # inter-chunk contribution uses the state entering this chunk
        y_int = jnp.einsum("bqhn,bhpn->bqhp", Cc, S) * jnp.exp(lac)[..., None]
        S_new = jnp.exp(la_end_c)[:, :, None, None] * S + Sc
        return S_new, y_int

    S0 = jnp.zeros((Bsz, H, P, N), F32)
    xs = (Cr.transpose(1, 0, 2, 3, 4), la.transpose(1, 0, 2, 3),
          la_end.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4))
    S_fin, y_inter = jax.lax.scan(scan_body, S0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # (B, nc, Q, H, P)

    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y, S_fin


def mamba_fwd(p, x, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence mamba2 block. x: (B, T, d) -> (B, T, d).

    With return_state=True also returns (conv_buf, ssm_state) at position T,
    so prefill can hand a decode-ready recurrent cache to the engine."""
    d_in, H, P, G, N = _dims(cfg)
    K = cfg.ssm.conv_dim
    B, T, _ = x.shape
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)

    zx = jnp.einsum("btd,de->bte", xn, p["w_zx"])
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = jnp.einsum("btd,de->bte", xn, p["w_bc"])
    dt = jax.nn.softplus(jnp.einsum("btd,dh->bth", xn, p["w_dt"]).astype(F32)
                         + p["dt_bias"])

    xbc_raw = jnp.concatenate([xin, bc], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]).astype(F32)).astype(x.dtype)
    xin, bc = xbc[..., :d_in], xbc[..., d_in:]
    Bm, Cm = jnp.split(bc.reshape(B, T, 2 * G, N), 2, axis=2)

    A = -jnp.exp(p["A_log"])
    xh = constrain(xin.reshape(B, T, H, P), "batch", None, "model", None)
    chunk = min(cfg.ssm.chunk_size, T)
    y, S_fin = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(F32)
    y = y.reshape(B, T, d_in).astype(x.dtype)

    y = L.rms_norm(y * jax.nn.silu(z.astype(F32)).astype(z.dtype), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    out = x + constrain(out, "batch", None, None)
    if not return_state:
        return out
    pad = max(K - 1 - T, 0)
    tail = jnp.pad(xbc_raw, ((0, 0), (pad, 0), (0, 0)))[:, -(K - 1):, :]
    return out, (tail.astype(x.dtype), S_fin)


def mamba_decode(p, x, state, cfg: ModelConfig):
    """One-token recurrent update. x: (B, 1, d); state = (conv_buf, S).

    conv_buf: (B, K-1, conv_ch)   S: (B, H, P, N) fp32
    """
    d_in, H, P, G, N = _dims(cfg)
    K = cfg.ssm.conv_dim
    conv_buf, S = state
    B = x.shape[0]
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)

    zx = jnp.einsum("btd,de->bte", xn, p["w_zx"])
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = jnp.einsum("btd,de->bte", xn, p["w_bc"])
    dt = jax.nn.softplus(jnp.einsum("btd,dh->bth", xn, p["w_dt"]).astype(F32)
                         + p["dt_bias"])[:, 0]          # (B, H)

    xbc_new = jnp.concatenate([xin, bc], axis=-1)[:, 0]  # (B, conv_ch)
    full = jnp.concatenate([conv_buf, xbc_new[:, None]], axis=1)  # (B, K, C)
    conv = jnp.einsum("bkc,kc->bc", full, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv.astype(F32)).astype(x.dtype)
    new_buf = full[:, 1:]

    xin1, bc1 = conv[..., :d_in], conv[..., d_in:]
    Bm, Cm = jnp.split(bc1.reshape(B, 2 * G, N), 2, axis=1)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(F32)         # (B, H, N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(F32)

    A = -jnp.exp(p["A_log"])
    xh = xin1.reshape(B, H, P).astype(F32)
    dA = jnp.exp(dt * A)                                 # (B, H)
    S = dA[:, :, None, None] * S + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, S) + p["D"][None, :, None] * xh

    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(F32)).astype(z.dtype), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return x + out, (new_buf, S)


def init_mamba_state(cfg: ModelConfig, batch: int):
    d_in, H, P, G, N = _dims(cfg)
    K = cfg.ssm.conv_dim
    conv_ch = d_in + 2 * G * N
    dtype = jnp.dtype(cfg.param_dtype)
    return (jnp.zeros((batch, K - 1, conv_ch), dtype),
            jnp.zeros((batch, H, P, N), F32))
