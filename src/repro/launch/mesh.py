"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets XLA_FLAGS before importing jax to
get 512 placeholder devices; real launches get devices from the Syndeo
runtime's gang allocation (one jax process per host, jax.distributed).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small virtual meshes, e.g. (2, 4))."""
    return jax.make_mesh(shape, axes)


def dp_degree(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)
