"""Roofline report: renders EXPERIMENTS.md-ready tables from the dry-run
artifacts (benchmarks/artifacts/dryrun/<tag>/<mesh>/*.json)."""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load(tag: str = "baseline", mesh: str = "singlepod") -> List[Dict]:
    out = []
    d = ART / tag / mesh
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def render_table(tag: str = "baseline", mesh: str = "singlepod") -> str:
    rows = [
        "| arch | shape | mem GiB | fits | compute_s | memory_s | collective_s"
        " | dominant | frac | MODEL/HLO |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(tag, mesh):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | -- |"
                        f" -- | skipped (sub-quadratic rule) | -- | -- |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        m, rf = r["memory"], r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {m['peak_per_device_gb']:.1f} |"
            f" {'y' if m['fits_16gb'] else 'OVER'} |"
            f" {rf['compute_s']:.3e} | {rf['memory_s']:.3e} |"
            f" {rf['collective_s']:.3e} | {rf['dominant'].replace('_s','')} |"
            f" {rf['roofline_fraction']:.3f} | {rf['useful_ratio']:.2f} |")
    return "\n".join(rows)


def summarize(tag: str = "baseline") -> Dict:
    out = {}
    for mesh in ("singlepod", "multipod"):
        recs = [r for r in load(tag, mesh)]
        ok = [r for r in recs if r["status"] == "ok"]
        out[mesh] = {
            "cells": len(recs),
            "ok": len(ok),
            "skipped": sum(r["status"] == "skipped" for r in recs),
            "errors": sum(r["status"] == "error" for r in recs),
            "fits": sum(r["memory"]["fits_16gb"] for r in ok),
            "dominant_memory": sum(
                r["roofline"]["dominant"] == "memory_s" for r in ok),
            "dominant_collective": sum(
                r["roofline"]["dominant"] == "collective_s" for r in ok),
        }
    return out


def main():
    print(json.dumps(summarize(), indent=1))
    for mesh in ("singlepod", "multipod"):
        print(f"\n### {mesh}\n")
        print(render_table("baseline", mesh))


if __name__ == "__main__":
    main()
