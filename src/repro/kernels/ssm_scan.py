"""Pallas TPU chunked SSD (Mamba-2) scan.

One grid step processes one (batch, head, chunk) cell: the intra-chunk
quasi-attention (Q x Q decay-masked scores on the MXU) plus the inter-chunk
contribution from the running state S, which lives in VMEM scratch across
the sequential chunk dimension -- the HBM traffic is x/B/C/dt once, y once,
state never (vs. the jnp reference whose scan carries round-trip every
chunk). This is the TPU-native shape of the SSD algorithm: within-chunk
parallel (MXU), across-chunk recurrent (VMEM-resident).

Layout: x (B,H,T,P); dt (B,H,T); A (H,1); Bm/Cm (B,G,T,N).
Out: y (B,H,T,P), final state (B,H,P,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref,
                s_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0, 0]                            # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    dA = dt * A
    la = jnp.cumsum(dA)                        # (Q,)
    la_end = la[chunk - 1]

    # intra-chunk: scores[t,s] = (C_t . B_s) * exp(la_t - la_s) * dt_s, s<=t
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = jnp.exp(jnp.clip(la[:, None] - la[None, :], -60.0, 0.0))
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w_intra = jnp.where(tri, scores * decay, 0.0) * dt[None, :]
    y = jax.lax.dot_general(w_intra, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk from carried state
    S = s_ref[...]                             # (P, N)
    y += jax.lax.dot_general(Cm, S, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * \
        jnp.exp(la)[:, None]

    # state update to chunk end
    w_state = jnp.exp(jnp.clip(la_end - la, -60.0, 0.0)) * dt   # (Q,)
    S_new = jnp.exp(la_end) * S + jax.lax.dot_general(
        x, Bm * w_state[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = S_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        s_out_ref[0, 0] = S_new.astype(s_out_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 256,
             interpret: bool = True):
    """x (B,H,T,P); dt (B,H,T); A (H,); Bm/Cm (B,G,T,N) -> (y, final_state)."""
    B, H, T, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    A2 = A.reshape(H, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h // rep, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A2, Bm, Cm)
    return y, s_fin
