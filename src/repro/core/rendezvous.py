"""Rendezvous: how workers find the head (paper §III-D phase 2-3).

The head writes its endpoint + cluster token to a *shared location*; workers
poll it and handshake. On Slurm that location is the shared filesystem; on a
cloud provider it is an object-store service (S3 etc.) -- both are the same
write-then-poll protocol, so FileRendezvous covers both (point it at the
shared FS mount or a FUSE-mounted bucket).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class Endpoint:
    host: str
    port: int
    cluster_id: str
    token: str


class FileRendezvous:
    def __init__(self, shared_dir: str):
        self.shared_dir = shared_dir
        os.makedirs(shared_dir, exist_ok=True)

    def _path(self, cluster_id: str) -> str:
        return os.path.join(self.shared_dir, f"head-{cluster_id}.json")

    def publish(self, ep: Endpoint):
        tmp = self._path(ep.cluster_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(ep.__dict__, f)
        os.replace(tmp, self._path(ep.cluster_id))  # atomic publish

    def wait(self, cluster_id: str, timeout: float = 60.0,
             poll: float = 0.05) -> Endpoint:
        deadline = time.monotonic() + timeout
        path = self._path(cluster_id)
        while time.monotonic() < deadline:
            if os.path.exists(path):
                with open(path) as f:
                    return Endpoint(**json.load(f))
            time.sleep(poll)
        raise TimeoutError(f"head endpoint for {cluster_id} not published")

    def retract(self, cluster_id: str):
        try:
            os.unlink(self._path(cluster_id))
        except FileNotFoundError:
            pass


class InMemoryRendezvous:
    def __init__(self):
        self._eps: Dict[str, Endpoint] = {}

    def publish(self, ep: Endpoint):
        self._eps[ep.cluster_id] = ep

    def wait(self, cluster_id: str, timeout: float = 5.0,
             poll: float = 0.01) -> Endpoint:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if cluster_id in self._eps:
                return self._eps[cluster_id]
            time.sleep(poll)
        raise TimeoutError(cluster_id)

    def retract(self, cluster_id: str):
        self._eps.pop(cluster_id, None)
