"""Reviewed suppressions for syndeo-lint findings.

``analysis/baseline.toml`` holds ``[[suppress]]`` entries::

    [[suppress]]
    rule = "SYN-L001"
    file = "worker.py"            # path suffix match
    function = "HeadServer.dispatch"   # optional, exact qualname
    match = "c.store.get"         # optional, message substring
    reason = "relay path: head-local store, bounded control ops"

``reason`` is mandatory: a suppression without a written justification
is a bug, not a baseline.  Parsed with :mod:`tomllib` when available
(Python >= 3.11); otherwise a minimal TOML-subset parser keeps the gate
usable on 3.10 without new dependencies.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.model import Finding

_REQUIRED = ("rule", "file", "reason")
_OPTIONAL = ("function", "match")


def load_baseline(path: str) -> List[Dict[str, str]]:
    text = Path(path).read_text()
    try:
        import tomllib
    except ModuleNotFoundError:
        data = _parse_toml_subset(text)
    else:
        data = tomllib.loads(text)
    entries = data.get("suppress", [])
    if not isinstance(entries, list):
        raise ValueError("baseline: [[suppress]] must be an array of "
                         "tables")
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            raise ValueError(f"baseline: suppress[{i}] is not a table")
        for k in _REQUIRED:
            if not isinstance(e.get(k), str) or not e[k]:
                raise ValueError(
                    f"baseline: suppress[{i}] needs non-empty "
                    f"string {k!r}")
        for k in e:
            if k not in _REQUIRED + _OPTIONAL:
                raise ValueError(
                    f"baseline: suppress[{i}] has unknown key {k!r}")
    return entries


def apply_baseline(
    findings: Sequence[Finding], entries: Sequence[Dict[str, str]],
) -> Tuple[List[Finding], List[Finding], List[Dict[str, str]]]:
    """Split findings into (unsuppressed, suppressed, unused entries)."""
    used: Set[int] = set()
    unsup: List[Finding] = []
    sup: List[Finding] = []
    for f in findings:
        idx = _match(f, entries)
        if idx is None:
            unsup.append(f)
        else:
            used.add(idx)
            sup.append(f)
    unused = [e for i, e in enumerate(entries) if i not in used]
    return unsup, sup, unused


def _match(f: Finding,
           entries: Sequence[Dict[str, str]]) -> Optional[int]:
    for i, e in enumerate(entries):
        if e["rule"] != f.rule:
            continue
        if not f.file.endswith(e["file"]):
            continue
        if e.get("function") and e["function"] != f.function:
            continue
        if e.get("match") and e["match"] not in f.message:
            continue
        return i
    return None


def _parse_toml_subset(text: str) -> Dict[str, object]:
    """Array-of-tables + scalar key/value lines; enough for a baseline
    file authored by this repo."""
    data: Dict[str, object] = {}
    current: Optional[Dict[str, object]] = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[[") and line.endswith("]]"):
            name = line[2:-2].strip()
            current = {}
            data.setdefault(name, [])
            arr = data[name]
            if not isinstance(arr, list):
                raise ValueError(f"baseline line {lineno}: {name!r} "
                                 "is both table and array")
            arr.append(current)
            continue
        if line.startswith("[") and line.endswith("]"):
            current = {}
            data[line[1:-1].strip()] = current
            continue
        if "=" in line:
            key, _, value = line.partition("=")
            target = current if current is not None else data
            target[key.strip()] = _parse_scalar(value.strip(), lineno)
            continue
        raise ValueError(f"baseline line {lineno}: unsupported syntax "
                         f"{raw!r}")
    return data


def _parse_scalar(v: str, lineno: int) -> object:
    if v.startswith('"') and v.endswith('"'):
        return json.loads(v)  # handles \" escapes
    if v in ("true", "false"):
        return v == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        raise ValueError(
            f"baseline line {lineno}: unsupported value {v!r}") from None
