"""Roofline analysis from compiled (SPMD-partitioned) HLO.

Why a parser: `compiled.cost_analysis()` counts a `while` body ONCE, but
scan-over-layers puts ~all FLOPs inside while loops. We parse
`compiled.as_text()` instead and multiply each computation's cost by the
product of enclosing while trip counts (XLA CPU prints
backend_config={"known_trip_count":{"n":"L"}} on while ops; we fall back to
the loop-bound constant in the cond computation).

Costs extracted (per device -- the partitioned module is the per-device
program):
  flops       : 2*prod(out)*prod(contracting dims) for every dot (including
                dots inside fusions), trip-count corrected.
  hbm bytes   : sum of (operands + output) sizes of top-level instructions;
                fusion internals are NOT counted (fused intermediates stay
                on-chip) -- this is the HBM-traffic proxy.
  collectives : per (kind): operand bytes and participant count, converted
                to effective wire bytes with ring-algorithm factors.

Roofline terms (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, active_param_count, param_count
from repro.configs.shapes import ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
LINK_BW = 50e9               # bytes / s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _scan_type(s: str, i: int) -> int:
    """Return index just past the type starting at s[i] (handles nested
    tuple types containing '/*index=N*/' comments)."""
    if s[i] == "(":
        depth = 0
        while i < len(s):
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
                if depth == 0:
                    return i + 1
            i += 1
        return i
    m = re.compile(r"\w+\[[^\]]*\](?:\{[^}]*\})?").match(s, i)
    return m.end() if m else i


def _parse_instr_line(raw: str):
    """-> (name, type_str, opcode, operand_body, attrs) or None."""
    m = _NAME_RE.match(raw)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    j = _scan_type(raw, i) if i < len(raw) else i
    if j == i:
        return None
    type_str = raw[i:j]
    mo = _OPCODE_RE.match(raw, j)
    if not mo:
        return None
    opcode = mo.group(1)
    # operand body: balance parens from the opcode's '('
    k = mo.end() - 1
    depth = 0
    end = len(raw)
    for idx in range(k, len(raw)):
        if raw[idx] == "(":
            depth += 1
        elif raw[idx] == ")":
            depth -= 1
            if depth == 0:
                end = idx
                break
    body = raw[k + 1:end]
    attrs = raw[end:]
    operands = re.findall(r"%([\w.\-]+)", body)
    return name, type_str, opcode, operands, attrs


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    # kind -> [ops, operand_bytes, wire_bytes]
    collectives: Dict[str, List[float]] = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            cur = self.collectives.setdefault(k, [0.0, 0.0, 0.0])
            for i in range(3):
                cur[i] += v[i] * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, CostTotals] = {}

    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            hdr = _COMP_HDR_RE.match(raw)
            if hdr and "=" not in raw.split("(")[0]:
                cur = hdr.group(2)
                self.computations[cur] = []
                if hdr.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if raw.strip() == "}":
                cur = None
                continue
            parsed = _parse_instr_line(raw)
            if parsed:
                name, type_str, opcode, operands, attrs = parsed
                self.computations[cur].append(
                    Instr(name, type_str, opcode, operands, attrs, raw))

    # -- helpers ------------------------------------------------------------

    def _symtab(self, comp: str) -> Dict[str, str]:
        return {i.name: i.type_str for i in self.computations.get(comp, [])}

    def _trip_count(self, instr: Instr) -> float:
        m = re.search(r'known_trip_count[^0-9]*(\d+)', instr.line)
        if m:
            return float(m.group(1))
        # fallback: constant in the cond computation
        mc = re.search(r"condition=%([\w.\-]+)", instr.line)
        if mc and mc.group(1) in self.computations:
            for ci in self.computations[mc.group(1)]:
                mm = re.match(r"s32\[\]", ci.type_str)
                if ci.opcode == "constant" and mm:
                    mv = re.search(r"constant\((\d+)\)", ci.line)
                    if mv:
                        return float(mv.group(1))
        return 1.0

    def _dot_flops(self, instr: Instr, symtab: Dict[str, str]) -> float:
        out = _shape_dims(instr.type_str)
        n_out = math.prod(out) if out else 1
        lhs_dims = ()
        if instr.operands:
            lhs_type = symtab.get(instr.operands[0], "")
            lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
        contract = 1
        if m and m.group(1) and lhs_dims:
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(lhs_dims):
                    contract *= lhs_dims[di]
        elif lhs_dims:
            contract = lhs_dims[-1]
        return 2.0 * n_out * contract

    def _participants(self, instr: Instr) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.line)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", instr.line)
        if m:
            return len(m.group(1).split(","))
        return 1

    # -- HBM-traffic helpers --------------------------------------------------

    def _fusion_operand_bytes(self, fusion_comp: str, symtab: Dict[str, str],
                              operands: List[str]) -> float:
        """Bytes actually *read* by a fusion: a parameter consumed only by
        dynamic-slice/gather ops inside the body is charged at the slice
        output size, not the full buffer (loop-invariant weight stacks and KV
        caches are sliced per iteration, not fully read)."""
        body = self.computations.get(fusion_comp, [])
        param_instr: Dict[int, str] = {}
        for i in body:
            if i.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.line)
                if m:
                    param_instr[int(m.group(1))] = i.name
        consumers: Dict[str, List[Instr]] = {}
        for i in body:
            for o in i.operands:
                consumers.setdefault(o, []).append(i)
        total = 0.0
        for idx, opname in enumerate(operands):
            full = _shape_bytes(symtab.get(opname, ""))
            pname = param_instr.get(idx)
            if pname is None:
                total += full
                continue
            cons = consumers.get(pname, [])
            if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
                total += sum(_shape_bytes(c.type_str) for c in cons)
            elif cons and all(c.opcode == "dynamic-update-slice"
                              and c.operands and c.operands[0] == pname
                              for c in cons):
                # in-place updated buffer: not read, write counted at output
                total += 0.0
            else:
                total += full
        return total

    def _fusion_output_bytes(self, fusion_comp: str, out_bytes: float) -> float:
        """A fusion whose root is dynamic-update-slice writes the update
        region (in-place), not the whole buffer."""
        body = self.computations.get(fusion_comp, [])
        for i in body:
            if i.line.lstrip().startswith("ROOT") and i.opcode == "dynamic-update-slice":
                symtab = self._symtab(fusion_comp)
                upd = i.operands[1] if len(i.operands) > 1 else None
                if upd:
                    return 2.0 * _shape_bytes(symtab.get(upd, ""))
        return out_bytes

    # -- cost walk ----------------------------------------------------------

    def comp_cost(self, comp: str, top_level: bool = True) -> CostTotals:
        key = f"{comp}|{top_level}"
        if key in self._memo:
            return self._memo[key]
        total = CostTotals()
        symtab = self._symtab(comp)
        for instr in self.computations.get(comp, []):
            op = instr.opcode
            out_bytes = _shape_bytes(instr.type_str)
            opnd_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in instr.operands)
            if op == "dynamic-slice" or op == "gather":
                total.bytes += 2.0 * out_bytes
                continue
            if op == "dynamic-update-slice":
                upd = instr.operands[1] if len(instr.operands) > 1 else None
                total.bytes += 2.0 * _shape_bytes(symtab.get(upd, "")) if upd else out_bytes
                continue
            if op == "scatter":
                upd = instr.operands[-1] if instr.operands else None
                total.bytes += 2.0 * _shape_bytes(symtab.get(upd, "")) if upd else out_bytes
                continue

            if op == "while":
                n = self._trip_count(instr)
                body = re.search(r"body=%([\w.\-]+)", instr.line)
                if body:
                    total.add(self.comp_cost(body.group(1)), n)
                cond = re.search(r"condition=%([\w.\-]+)", instr.line)
                if cond:
                    total.add(self.comp_cost(cond.group(1)), n)
                continue
            if op in ("call", "conditional"):
                for target in re.findall(r"(?:to_apply|true_computation|false_computation|called_computations)=\{?%([\w.\-]+)", instr.line):
                    total.add(self.comp_cost(target), 1.0)
                total.bytes += out_bytes + opnd_bytes
                continue
            if op == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", instr.line)
                if m:
                    fc = m.group(1)
                    sub = self.comp_cost(fc, top_level=False)
                    total.flops += sub.flops
                    total.transcendentals += sub.transcendentals
                    # fusion internals stay on-chip: bytes = boundary only,
                    # with slice-aware reads and in-place DUS writes
                    total.bytes += (self._fusion_output_bytes(fc, out_bytes)
                                    + self._fusion_operand_bytes(fc, symtab,
                                                                 instr.operands))
                else:
                    total.bytes += out_bytes + opnd_bytes
                continue
            if op == "dot" or op == "convolution":
                total.flops += self._dot_flops(instr, symtab)
                if top_level:
                    total.bytes += out_bytes + opnd_bytes
                continue
            if op in _COLLECTIVES or (op.endswith("-start") and op[:-6] in _COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                parts = self._participants(instr)
                b = opnd_bytes
                if kind == "all-reduce":
                    wire = 2.0 * b * (parts - 1) / max(parts, 1)
                elif kind == "all-gather":
                    wire = b * (parts - 1)
                elif kind in ("reduce-scatter", "all-to-all"):
                    wire = b * (parts - 1) / max(parts, 1)
                else:  # collective-permute
                    wire = b
                cur = total.collectives.setdefault(kind, [0.0, 0.0, 0.0])
                cur[0] += 1
                cur[1] += b
                cur[2] += wire
                total.bytes += out_bytes + opnd_bytes
                continue
            if op in ("tanh", "exponential", "log", "power", "rsqrt", "sqrt",
                      "logistic", "exponential-minus-one", "log-plus-one"):
                dims = _shape_dims(instr.type_str)
                total.transcendentals += math.prod(dims) if dims else 1
            if top_level and op not in _SKIP_BYTES_OPS:
                total.bytes += out_bytes + opnd_bytes
        self._memo[key] = total
        return total

    def entry_cost(self) -> CostTotals:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


# ----------------------------------------------------------------------------
# Roofline terms
# ----------------------------------------------------------------------------

def roofline_terms(cost: CostTotals) -> Dict[str, float]:
    wire = sum(v[2] for v in cost.collectives.values())
    return {
        "compute_s": cost.flops / PEAK_FLOPS,
        "memory_s": cost.bytes / HBM_BW,
        "collective_s": wire / LINK_BW,
        "hlo_flops_per_device": cost.flops,
        "hbm_bytes_per_device": cost.bytes,
        "wire_bytes_per_device": wire,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    keys = ["compute_s", "memory_s", "collective_s"]
    return max(keys, key=lambda k: terms[k])


def roofline_fraction(terms: Dict[str, float]) -> float:
    """compute-term / max-term: 1.0 == perfectly compute-bound (roofline)."""
    top = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return terms["compute_s"] / top if top > 0 else 0.0


# ----------------------------------------------------------------------------
# Analytic MODEL_FLOPS (global, whole step)
# ----------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D-style useful-math FLOPs for the whole (global) step."""
    B, T = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    n_act = active_param_count(cfg)
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_act_noemb = n_act - emb
    # attention context math per attn layer
    if cfg.family in ("dense", "moe", "vlm"):
        n_attn = cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.hybrid.attn_every
    elif cfg.family == "audio":
        n_attn = cfg.n_layers * 2 + cfg.encdec.n_enc_layers  # self+cross+enc
    else:
        n_attn = 0

    if shape.kind == "train":
        matmul = 6.0 * n_act * B * T
        attn = n_attn * 12.0 * B * T * T * cfg.n_heads * hd * 0.5
        return matmul + attn
    if shape.kind == "prefill":
        return 2.0 * n_act * B * T + n_attn * 4.0 * B * T * T * cfg.n_heads * hd * 0.5
    # decode: one token, context = T (or the window for windowed layers)
    ctx = T
    if cfg.long_context_window and shape.name == "long_500k":
        ctx = cfg.long_context_window
    attn = n_attn * 4.0 * B * ctx * cfg.n_heads * hd
    ssm = 0.0
    if cfg.family in ("hybrid", "ssm"):
        # recurrent state update flops are tiny; covered by matmul term
        pass
    return 2.0 * n_act * B + attn + ssm
