"""known-bad: actor-directive sub-ops drift from the handler set
(SYN-W001 on a queued directive with no handler, SYN-W002 when the only
actor_call send drops the payload its handler subscripts, SYN-W003 on an
actor_create reply without ok/error)."""


class Server:
    def __init__(self):
        self.actors = {}

    def dispatch(self, msg):
        op = msg.get("op")
        if op == "actor_create":
            self.actors[msg["actor"]] = msg["factory"]
            return {"created": msg["actor"]}          # reply lacks ok/error
        if op == "actor_call":
            value = self.actors[msg["actor"]](msg["payload"])
            return {"ok": True, "value": value}
        if op == "actor_exit":
            self.actors.pop(msg["actor"], None)
            return {"ok": True}
        return {"ok": False, "error": f"bad op {op}"}


def head_poll_reply(outbox):
    outbox.append({"op": "actor_create", "actor": "a", "factory": "F"})
    outbox.append({"op": "actor_call", "actor": "a"})    # missing "payload"
    outbox.append({"op": "actor_pause", "actor": "a"})   # typo: no handler
    return {"ok": True,
            "actor_ops": outbox + [{"op": "actor_exit", "actor": "a"}]}
