"""SyndeoCluster: the four bring-up phases (paper §III-D) + client API.

  1. *Creating the container* -- a ContainerSpec (image, env, binds) is built
     offline (root needed only there) and copied to every node; here the
     spec is validated and serialized (backends/containers.py render the
     actual Apptainer/K8s/Slurm artifacts).
  2. *Starting the head* -- head endpoint + cluster token published via the
     rendezvous (shared FS / object-store service).
  3. *Adding workers* -- each node reads the rendezvous, HMAC-handshakes,
     registers its resources and joins the Global Object Store.
  4. *Running* -- jobs submitted at the head execute under the dynamic
     scheduler (scheduler-inside-a-scheduler).

The local backend runs workers as unprivileged *threads* in-process (one
python process == one container stand-in); the same Scheduler/ObjectStore
code is driven by the simulation backend for the paper-scale benchmarks and
by generated sbatch/K8s artifacts for real deployments.
"""
from __future__ import annotations

import queue
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.object_store import (GlobalObjectStore, NodeStore, ObjectRef,
                                     TenantQuota)
from repro.core.rendezvous import Endpoint, InMemoryRendezvous
from repro.core.scheduler import Scheduler, SchedulerConfig, WorkerInfo
from repro.core.security import (DEFAULT_TENANT, Capability, NonceCache,
                                 SecurityError, Tenant,
                                 UnprivilegedProfile, mint_cluster_token,
                                 open_sealed, seal)
from repro.core.task_graph import Task, TaskSpec, TaskState


@dataclass(frozen=True)
class ContainerSpec:
    """What every node must have a copy of (paper phase 1)."""
    image: str = "syndeo.sif"
    base: str = "docker://python:3.11-slim"
    env: Dict[str, str] = field(default_factory=dict)
    binds: List[str] = field(default_factory=list)     # host:container
    sandbox_writable: bool = True                       # Apptainer --writable-tmpfs
    entrypoint: str = "python -m repro.core.worker"


class SyndeoCluster:
    """Head node + client API. Thread-safe."""

    def __init__(self, container: Optional[ContainerSpec] = None,
                 scheduler_config: SchedulerConfig = SchedulerConfig(),
                 profile: Optional[UnprivilegedProfile] = None,
                 rendezvous=None, data_plane: str = "p2p"):
        self.container = container or ContainerSpec()
        # "p2p" (default): TCP workers run blob servers, results stay on
        # the producer, the head serves metadata + transfer tickets only.
        # "relay": every payload rides the head's socket -- the single-node
        # backward-compat mode and the benchmark baseline. The threaded
        # local backend is in-process either way; HeadServer reads this.
        self.data_plane = data_plane
        self.cluster_id = uuid.uuid4().hex[:12]
        self.token = mint_cluster_token()
        self.profile = profile or UnprivilegedProfile(allow_root=True)
        self.profile.enforce()
        self.rendezvous = rendezvous or InMemoryRendezvous()
        # the directory's shard count rides the scheduler config: one knob
        # sizes both halves of the control plane (shards=1 == the seed)
        self.store = GlobalObjectStore(shards=scheduler_config.shards)
        self._nonces = NonceCache()   # replay guard for join handshakes
        self._lock = threading.RLock()
        self._queues: Dict[str, "queue.Queue"] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self._futures: Dict[str, threading.Event] = {}
        self._worker_seq = 0          # monotonic: retired ids never reused
        self.autoscaler = None        # set by attach_autoscaler
        self._stop = threading.Event()
        self.scheduler = Scheduler(self.store, self._launch, self._cancel,
                                   scheduler_config)
        self._head_node = NodeStore("head", capacity_bytes=1 << 30,
                                    spill_dir=self.profile.scratch_dir(self.cluster_id))
        self.store.register_node(self._head_node)
        # drain migrations are capability-checked under the cluster token:
        # only the head (which minted this grant) may move objects around.
        # The grant is cluster-scoped (admin), so head-driven drains may
        # migrate any tenant's objects; tenant-scoped capabilities cannot.
        self.store.set_migration_guard(
            Capability.grant(self.token, "objects", "migrate"), self.token)
        # tenant capabilities presented on get/put are verified against this
        self.store.set_access_guard(self.token)
        # worker-destined transfers must carry a head-minted ticket whose
        # MAC binds (object, source, destination worker, tenant, expiry)
        self.store.set_transfer_guard(True)
        self._tenants: Dict[str, Tenant] = {}
        self._tenant_min: Dict[str, int] = {}
        self._actors: Dict[str, Any] = {}   # actor_id -> live instance
        self.rendezvous.publish(Endpoint("127.0.0.1", 6379, self.cluster_id,
                                         self.token))

    # -- multi-tenancy ---------------------------------------------------------

    def register_tenant(self, tenant_id: str, weight: float = 1.0,
                        quota_bytes: Optional[int] = None,
                        quota_refs: Optional[int] = None,
                        on_exceed: str = "reject",
                        min_workers: int = 0,
                        submit_rate: Optional[float] = None,
                        submit_burst: Optional[float] = None,
                        quota_bytes_per_node: Optional[int] = None) -> Tenant:
        """Admit a tenant: fair-share weight on the scheduler, byte/ref
        quota on the object store, an optional token-bucket submit rate
        (`submit_rate` tasks/s sustained, `submit_burst` peak -- exceeding
        it raises RateLimitExceeded exactly like a quota reject), a
        scale-down floor on the autoscaler, and a derived per-tenant key
        the tenant mints capabilities with (the tenant never sees the
        cluster token)."""
        with self._lock:
            self.scheduler.register_tenant(tenant_id, weight)
            if (quota_bytes is not None or quota_refs is not None
                    or quota_bytes_per_node is not None):
                self.store.set_quota(tenant_id, TenantQuota(
                    max_bytes=quota_bytes, max_refs=quota_refs,
                    on_exceed=on_exceed,
                    max_bytes_per_node=quota_bytes_per_node))
            if submit_rate is not None:
                self.scheduler.set_submit_rate(tenant_id, submit_rate,
                                               submit_burst)
            if min_workers:
                self._tenant_min[tenant_id] = min_workers
                if self.autoscaler is not None:
                    self.autoscaler.cfg.tenant_min_workers[tenant_id] = \
                        min_workers
            tenant = Tenant.derive(self.token, tenant_id, weight)
            self._tenants[tenant_id] = tenant
        return tenant

    def tenant(self, tenant_id: str) -> Optional[Tenant]:
        return self._tenants.get(tenant_id)

    # -- phase 3: workers join -------------------------------------------------

    def add_worker(self, worker_id: Optional[str] = None,
                   resources: Optional[Dict[str, float]] = None,
                   start_thread: bool = True) -> str:
        """Handshake + register (paper phase 3). Threaded local backend."""
        ep = self.rendezvous.wait(self.cluster_id)
        hello = seal(ep.token, {"op": "join", "worker": worker_id or "?"})
        # head verifies the HMAC handshake; the nonce cache rejects a
        # replayed hello that would re-register a retired worker id
        open_sealed(self.token, hello, nonce_cache=self._nonces)

        if worker_id is None:
            worker_id = f"w{self._worker_seq}"
        self._worker_seq += 1
        wid = worker_id
        store = NodeStore(wid, capacity_bytes=256 << 20,
                          spill_dir=self.profile.scratch_dir(self.cluster_id))
        self.store.register_node(store)
        q: "queue.Queue" = queue.Queue()
        with self._lock:
            self._queues[wid] = q
            self.scheduler.add_worker(
                WorkerInfo(wid, resources or {"cpu": 1.0}))
        if start_thread:
            t = threading.Thread(target=self._worker_loop, args=(wid, q),
                                 daemon=True, name=f"syndeo-{wid}")
            self._threads[wid] = t
            t.start()
        return wid

    def remove_worker(self, worker_id: str):
        with self._lock:
            self.scheduler.on_worker_failed(worker_id, reason="removed")
        q = self._queues.pop(worker_id, None)
        if q is not None:
            q.put(None)

    def drain_worker(self, worker_id: str,
                     deadline_s: Optional[float] = None,
                     timeout: float = 10.0) -> bool:
        """Graceful retirement of one worker: DRAINING (no new placements),
        running tasks finish (threads are cooperative, so the deadline only
        stops the wait -- it cannot preempt a mid-flight python call), hot
        objects migrate to survivors, then the thread is stopped. Returns
        False (and cancels the drain) if the worker cannot drain in time."""
        with self._lock:
            if not self.scheduler.begin_drain(worker_id, deadline_s):
                return False
        limit = time.monotonic() + timeout
        while time.monotonic() < limit:
            with self._lock:
                if self.scheduler.drain_complete(worker_id) \
                        and self.scheduler.finish_drain(worker_id):
                    q = self._queues.pop(worker_id, None)
                    if q is not None:
                        q.put(None)
                    self._threads.pop(worker_id, None)
                    return True
            time.sleep(0.02)
        with self._lock:
            self.scheduler.cancel_drain(worker_id)
        return False

    # -- elasticity (paper gap: the gang allocation can now grow/shrink) -------

    def attach_autoscaler(self, config=None):
        """Attach an elastic autoscaler driven by the head's health loop.
        New workers join as local threads; idle workers are retired
        gracefully (their threads drain on the queue sentinel)."""
        from repro.core.autoscaler import Autoscaler, AutoscalerConfig
        cfg = config or AutoscalerConfig()

        def provision(count: int, resources: Dict[str, float]) -> int:
            for _ in range(count):
                wid = self.add_worker(resources=dict(resources))
                self.autoscaler.note_joined(wid)
            return count

        def release(worker_ids: List[str]):
            # scheduler-side retirement already happened (retire_worker);
            # stop the threads and drop the queues
            for wid in worker_ids:
                q = self._queues.pop(wid, None)
                if q is not None:
                    q.put(None)
                self._threads.pop(wid, None)

        cfg.tenant_min_workers.update(self._tenant_min)
        self.autoscaler = Autoscaler(self.scheduler, provision, release, cfg)
        return self.autoscaler

    # -- phase 4: run ------------------------------------------------------------

    def submit(self, fn: Callable[..., Any], *args,
               resources: Optional[Dict[str, float]] = None,
               deps: Optional[List[ObjectRef]] = None,
               group: str = "default", name: str = "",
               max_retries: int = 3,
               placement_group: Optional[str] = None,
               bundle_index: Optional[int] = None,
               tenant_id: str = DEFAULT_TENANT, **kwargs) -> Task:
        spec = TaskSpec(fn=fn, args=args, kwargs=kwargs,
                        resources=resources or {"cpu": 1.0},
                        group=group, name=name or getattr(fn, "__name__", "task"),
                        max_retries=max_retries,
                        placement_group=placement_group,
                        bundle_index=bundle_index,
                        tenant_id=tenant_id)
        with self._lock:
            task = self.scheduler.submit(spec, deps)
            self._futures[task.id] = threading.Event()
        return task

    def put(self, value: Any, tenant_id: str = DEFAULT_TENANT,
            capability: Optional[Capability] = None) -> ObjectRef:
        return self.store.put("head", value, tenant=tenant_id,
                              capability=capability)

    def get(self, task_or_ref, timeout: float = 60.0) -> Any:
        if isinstance(task_or_ref, ObjectRef):
            value = self.store.get("head", task_or_ref)
            # replica GC hint: this head copy serves a client read, not
            # the data plane -- it is released when the refcount next
            # drops instead of lingering for the cluster lifetime
            self.store.mark_client_read(task_or_ref)
            return value
        task = task_or_ref
        ev = self._futures.get(task.id)
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            output = None
            with self._lock:
                cur = self.scheduler.graph.tasks.get(task.id)
                if cur and cur.state == TaskState.FAILED:
                    raise RuntimeError(f"task failed: {cur.error}")
                if cur and cur.state == TaskState.FINISHED:
                    output = cur.output
            if output is not None:
                try:
                    # the blob fetch may cross the network (a p2p worker
                    # holds the primary): NEVER under the cluster lock, or
                    # one slow source stalls every control-plane op
                    value = self.store.get("head", output)
                    self.store.mark_client_read(output)
                    return value
                except KeyError:
                    # output's only copy died with its worker: lineage
                    # reconstruction -- re-run the producing task
                    with self._lock:
                        cur = self.scheduler.graph.tasks.get(task.id)
                        if cur and cur.state == TaskState.FINISHED:
                            self.store.note_reconstruction()
                            cur.state = TaskState.READY
                            cur.output = None
                            cur.attempts = 0
                            self.scheduler._enqueue_ready(cur)
                            self.scheduler.schedule()
                    continue
            if ev is not None:
                ev.wait(0.02)
                ev.clear()
            else:
                time.sleep(0.02)
        raise TimeoutError(f"task {task.id} not finished in {timeout}s")

    def wait_all(self, tasks: List[Task], timeout: float = 120.0) -> List[Any]:
        return [self.get(t, timeout=timeout) for t in tasks]

    def create_placement_group(self, name: str, bundles, strategy="SPREAD"):
        with self._lock:
            return self.scheduler.create_placement_group(name, bundles, strategy)

    # -- service actors (threaded twin of the wire protocol's actor ops) --------

    def create_actor(self, actor_id: str, factory: Callable[[], Any],
                     resources: Optional[Dict[str, float]] = None,
                     tenant_id: str = DEFAULT_TENANT,
                     placement_group: Optional[str] = None,
                     bundle_index: Optional[int] = None) -> Optional[str]:
        """Place a long-running service actor (lifetime resource hold via
        `place_actor`) and instantiate it in-process. Returns the hosting
        worker id, or None when nothing fits. The instance must expose
        `handle(payload) -> value`; a `drain()` method, if present, runs
        before a graceful exit (replica finishes in-flight decodes)."""
        with self._lock:
            wid = self.scheduler.place_actor(
                actor_id, resources or {"cpu": 1.0}, tenant_id=tenant_id,
                placement_group=placement_group, bundle_index=bundle_index)
            if wid is None:
                return None
            try:
                self._actors[actor_id] = factory()
            except Exception:
                self.scheduler.remove_actor(actor_id)
                raise
        return wid

    def call_actor(self, actor_id: str, payload: Any) -> Any:
        """Synchronous actor call on the caller's thread (threads are
        cooperative here, like task execution). Raises KeyError for an
        unknown or already-exited actor."""
        inst = self._actors[actor_id]
        return inst.handle(payload)

    def destroy_actor(self, actor_id: str) -> bool:
        """Graceful actor exit: drain in-flight work (if the instance
        supports it), then release the lifetime resource hold."""
        inst = self._actors.pop(actor_id, None)
        if inst is not None and hasattr(inst, "drain"):
            inst.drain()
        with self._lock:
            return self.scheduler.remove_actor(actor_id)

    # -- backend plumbing (threaded local workers) -----------------------------------

    def _launch(self, task: Task, worker_id: str):
        q = self._queues.get(worker_id)
        if q is not None:
            q.put(task.id)

    def _cancel(self, task: Task, worker_id: str):
        pass  # threads are cooperative; results of cancelled twins are dropped

    def _worker_loop(self, wid: str, q: "queue.Queue"):
        while not self._stop.is_set():
            try:
                tid = q.get(timeout=0.1)
            except queue.Empty:
                with self._lock:
                    self.scheduler.heartbeat(wid)
                continue
            if tid is None:
                return
            with self._lock:
                task = self.scheduler.graph.tasks.get(tid)
                if task is None or task.state != TaskState.RUNNING:
                    continue
                spec, deps = task.spec, list(task.deps)
            try:
                # the worker acts *as the task's tenant*: every dep fetch and
                # the result put present a tenant-scoped capability that the
                # store verifies against the object's owner -- a task cannot
                # read or overwrite another tenant's objects
                tenant = spec.tenant_id
                resolved = []
                for d in deps:
                    # every remote dep fetch rides the ticketed data plane:
                    # grant_fetch picks the source (locality + link load)
                    # and refuses cross-tenant reads at mint time
                    cap = Capability.grant_for_tenant(
                        self.token, tenant, d.id, "get")
                    ticket = self.store.grant_fetch(d, wid, tenant)
                    try:
                        resolved.append(self.store.get(
                            wid, d, capability=cap, ticket=ticket))
                    except KeyError:
                        # the ticket-pinned source lost its copy (e.g. it
                        # migrated mid-drain): re-mint against a survivor
                        # before burning a task retry
                        ticket = self.store.grant_fetch(d, wid, tenant)
                        resolved.append(self.store.get(
                            wid, d, capability=cap, ticket=ticket))
                out = spec.fn(*spec.args, *resolved, **spec.kwargs)
                ref = self.store.put(
                    wid, out, producer_task=tid, ref_id=f"obj-{tid}",
                    tenant=tenant,
                    capability=Capability.grant_for_tenant(
                        self.token, tenant, f"obj-{tid}", "put"))
                with self._lock:
                    self.scheduler.on_task_finished(tid, ref, worker_id=wid)
            except Exception as e:  # noqa: BLE001 -- worker never dies on task error
                with self._lock:
                    self.scheduler.on_task_failed(
                        tid, f"{type(e).__name__}: {e}", worker_id=wid)
            ev = self._futures.get(tid)
            if ev is not None:
                ev.set()

    # -- lifecycle ---------------------------------------------------------------

    def health_check(self):
        with self._lock:
            self.scheduler.check_liveness()
            self.scheduler.check_stragglers()
            self.scheduler.check_drains()
            if self.autoscaler is not None:
                self.autoscaler.tick()

    def shutdown(self):
        self._stop.set()
        for q in self._queues.values():
            q.put(None)
        for t in self._threads.values():
            t.join(timeout=2.0)
        self.rendezvous.retract(self.cluster_id)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
