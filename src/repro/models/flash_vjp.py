"""Blockwise flash attention with a custom VJP (the flash *backward*).

Why: differentiating the online-softmax scan lets JAX stack per-iteration
residuals (p, acc, m, l) to HBM -- measured as the dominant HBM-traffic term
of every train cell in the baseline roofline (EXPERIMENTS.md §Perf it1).
The flash backward instead saves only (q, k, v, o, lse) and *recomputes* p
per (q-block, kv-block) tile, exactly like the production Pallas backward
kernel it validates.

Layout matches layers.flash_attention_ref: q (B,Tq,Hq,D), k/v (B,Tk,Hkv,D).
Causal + sliding-window; fp32 softmax; GQA folded (kv never repeated).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def _footprint(i, nq, nk, block_q, block_k, q_offset, causal, window):
    q_start = q_offset + i * block_q
    q_end = q_start + block_q - 1
    hi = nk if not causal else min(nk, (q_end // block_k) + 1)
    lo = 0
    if window is not None:
        lo = max(0, (q_start - window + 1) // block_k)
    return q_start, lo, hi


def _mask_for(q_start, j, block_q, block_k, causal, window):
    qpos = q_start + jnp.arange(block_q)
    kpos = j * block_k + jnp.arange(block_k)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def _fwd_impl(q, k, v, causal, window, q_offset, block_q, block_k):
    B, Tq, Hq, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    R = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    nq, nk = Tq // block_q, Tk // block_k
    qr = q.reshape(B, nq, block_q, Hkv, R, Dh)
    kr = k.reshape(B, nk, block_k, Hkv, Dh)
    vr = v.reshape(B, nk, block_k, Hkv, Dh)

    outs, lses = [], []
    for i in range(nq):
        q_blk = qr[:, i]
        q_start, lo, hi = _footprint(i, nq, nk, block_q, block_k, q_offset,
                                     causal, window)
        n_steps = hi - lo
        if n_steps <= 0:
            outs.append(jnp.zeros((B, block_q, Hkv, R, Dh), q.dtype))
            lses.append(jnp.full((B, Hkv, R, block_q), NEG_INF, F32))
            continue

        def body(carry, j):
            acc, m, l = carry
            kb = jax.lax.dynamic_index_in_dim(kr, j, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, j, 1, keepdims=False)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", q_blk.astype(F32),
                           kb.astype(F32)) * scale
            mask = _mask_for(q_start, j, block_q, block_k, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
            l_new = l * alpha + jnp.sum(p, -1)
            acc_new = acc * alpha[..., None] + \
                jnp.einsum("bhrqk,bkhd->bhrqd", p, vb.astype(F32))
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Hkv, R, block_q, Dh), F32)
        m0 = jnp.full((B, Hkv, R, block_q), NEG_INF, F32)
        l0 = jnp.zeros((B, Hkv, R, block_q), F32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                      lo + jnp.arange(n_steps))
        l_safe = jnp.maximum(l, 1e-20)
        outs.append((acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)
                    .astype(q.dtype))
        lses.append(m + jnp.log(l_safe))

    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    lse = jnp.stack(lses, axis=1)                 # (B, nq, Hkv, R, bq)
    return out.reshape(B, Tq, Hq, Dh), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_vjp(q, k, v, causal=True, window=None, q_offset=0,
                        block_q=512, block_k=512):
    out, _ = _fwd_impl(q, k, v, causal, window, q_offset, block_q, block_k)
    return out


def _fwd(q, k, v, causal, window, q_offset, block_q, block_k):
    out, lse = _fwd_impl(q, k, v, causal, window, q_offset, block_q, block_k)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, q_offset, block_q, block_k, res, do):
    q, k, v, out, lse = res
    B, Tq, Hq, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    R = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    nq, nk = Tq // block_q, Tk // block_k

    qr = q.reshape(B, nq, block_q, Hkv, R, Dh)
    kr = k.reshape(B, nk, block_k, Hkv, Dh)
    vr = v.reshape(B, nk, block_k, Hkv, Dh)
    do_r = do.reshape(B, nq, block_q, Hkv, R, Dh)
    o_r = out.reshape(B, nq, block_q, Hkv, R, Dh)
    # delta = rowsum(do * o)  (B, nq, Hkv, R, bq)
    delta = jnp.einsum("bnqhrd,bnqhrd->bnhrq", do_r.astype(F32),
                       o_r.astype(F32))

    dq = jnp.zeros((B, nq, block_q, Hkv, R, Dh), F32)
    dk = jnp.zeros((B, nk, block_k, Hkv, Dh), F32)
    dv = jnp.zeros((B, nk, block_k, Hkv, Dh), F32)

    for i in range(nq):
        q_blk = qr[:, i].astype(F32)
        do_blk = do_r[:, i].astype(F32)
        lse_i = lse[:, i]                         # (B,Hkv,R,bq)
        delta_i = delta[:, i]
        q_start, lo, hi = _footprint(i, nq, nk, block_q, block_k, q_offset,
                                     causal, window)
        n_steps = hi - lo
        if n_steps <= 0:
            continue

        def body(dq_acc, j):
            kb = jax.lax.dynamic_index_in_dim(kr, j, 1, keepdims=False).astype(F32)
            vb = jax.lax.dynamic_index_in_dim(vr, j, 1, keepdims=False).astype(F32)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", q_blk, kb) * scale
            mask = _mask_for(q_start, j, block_q, block_k, causal, window)
            p = jnp.exp(s - lse_i[..., None]) * mask[None, None, None]
            dp = jnp.einsum("bqhrd,bkhd->bhrqk", do_blk, vb)
            ds = p * (dp - delta_i[..., None]) * scale
            dq_new = dq_acc + jnp.einsum("bhrqk,bkhd->bqhrd", ds, kb)
            dv_j = jnp.einsum("bhrqk,bqhrd->bkhd", p, do_blk)
            dk_j = jnp.einsum("bhrqk,bqhrd->bkhd", ds, q_blk)
            return dq_new, (dk_j, dv_j)

        dq_i0 = jnp.zeros((B, block_q, Hkv, R, Dh), F32)
        dq_i, (dk_js, dv_js) = jax.lax.scan(body, dq_i0,
                                            lo + jnp.arange(n_steps))
        dq = dq.at[:, i].set(dq_i)
        # scatter the contiguous kv footprint back (static offsets)
        dk_js = dk_js.transpose(1, 0, 2, 3, 4)    # (B, n_steps, bk, Hkv, D)
        dv_js = dv_js.transpose(1, 0, 2, 3, 4)
        dk = dk.at[:, lo:hi].add(dk_js)
        dv = dv.at[:, lo:hi].add(dv_js)

    dq = dq.reshape(B, Tq, Hq, Dh).astype(q.dtype)
    dk = dk.reshape(B, Tk, Hkv, Dh).astype(k.dtype)
    dv = dv.reshape(B, Tk, Hkv, Dh).astype(v.dtype)
    return dq, dk, dv


flash_attention_vjp.defvjp(_fwd, _bwd)
