"""internvl2-76b  [arXiv:2404.16821] -- InternViT + InternLM2 backbone.
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings already projected to d_model (prepended to the token sequence).
FSDP weight sharding: 152 GB bf16 over model=16 alone would be 9.5 GB/chip
before activations/optimizer."""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    vlm=VLMConfig(n_patches=256),
    fsdp=True,
    kv_replication=2,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    vlm=VLMConfig(n_patches=8),
)
