"""Suite-wide sanitizer: every test must clean up its threads and
sockets.

The real-socket suites (test_drain_p2p.py, test_dataplane.py) spin up
head servers, blob servers and worker threads; a test that forgets
``shutdown()`` strands daemon threads and listening-socket fds that
silently poison later tests (port exhaustion, cross-test chatter).
This autouse fixture snapshots live threads and open socket fds before
each test and fails the test if new ones survive a short grace period.

Grace period: worker loops exit on their poll cadence and daemon
servers wind down asynchronously, so teardown is given a few seconds
to converge before the leak is called real.  The check exits as soon
as everything is clean -- a leak-free test pays ~0ms.
"""
import os
import threading
import time

import pytest

_GRACE_S = 8.0

# Thread-name prefixes that may legitimately outlive a single test
# (none today; extend deliberately, with a comment, never to shut the
# sanitizer up).
_ALLOWED_THREAD_PREFIXES: tuple = ()


def _live_threads():
    return {t for t in threading.enumerate()
            if t.is_alive()
            and not any(t.name.startswith(p)
                        for p in _ALLOWED_THREAD_PREFIXES)}


def _open_socket_fds():
    """fd -> 'socket:[inode]' via /proc; degrades to empty off-Linux
    (the thread check still runs there)."""
    out = {}
    try:
        fds = os.listdir("/proc/self/fd")
    except OSError:
        return out
    for fd in fds:
        try:
            target = os.readlink(f"/proc/self/fd/{fd}")
        except OSError:
            continue  # fd closed between listdir and readlink
        if target.startswith("socket:"):
            out[fd] = target
    return out


@pytest.fixture(autouse=True)
def no_thread_or_socket_leaks(request):
    before_threads = _live_threads()
    before_socks = _open_socket_fds()
    yield
    deadline = time.monotonic() + _GRACE_S
    while True:
        new_threads = {t for t in _live_threads() - before_threads
                       if t.is_alive()}
        # an fd number can be recycled for a different socket inode:
        # compare fd->inode pairs, not just fd presence
        new_socks = {fd: tgt
                     for fd, tgt in _open_socket_fds().items()
                     if before_socks.get(fd) != tgt}
        if not new_threads and not new_socks:
            return
        if time.monotonic() > deadline:
            break
        time.sleep(0.05)
    lines = []
    if new_threads:
        lines.append("leaked threads: "
                     + ", ".join(sorted(t.name for t in new_threads)))
    if new_socks:
        lines.append("leaked socket fds: "
                     + ", ".join(f"{fd}={tgt}"
                                 for fd, tgt in sorted(new_socks.items())))
    pytest.fail(f"{request.node.nodeid} leaked resources after "
                f"{_GRACE_S:.0f}s grace -- " + "; ".join(lines),
                pytrace=False)
