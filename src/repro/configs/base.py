"""Configuration dataclasses for the architecture zoo.

Every assigned architecture gets one module in this package defining:
  CONFIG : ModelConfig  -- the exact published configuration
  SMOKE  : ModelConfig  -- a reduced same-family config for CPU smoke tests

Shapes (train_4k / prefill_32k / decode_32k / long_500k) live in `shapes.py`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Arctic keeps a small dense FFN residual alongside the MoE FFN.
    dense_residual_ff: int = 0
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style state-space block parameters."""
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64          # mamba2 heads: d_inner // head_dim
    chunk_size: int = 256
    conv_dim: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack: mLSTM with a periodic sLSTM block."""
    slstm_every: int = 8        # 7:1 mLSTM:sLSTM
    mlstm_expand: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_dim: int = 4


@dataclass(frozen=True)
class HybridConfig:
    """zamba2-style hybrid: mamba2 backbone + shared attention block."""
    attn_every: int = 6         # one (shared) attention block per 6 mamba blocks
    shared_attention: bool = True


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder split (conv frontend is a stub)."""
    n_enc_layers: int = 4
    enc_seq_ratio: float = 1.0  # encoder frames per decoder token in train shapes


@dataclass(frozen=True)
class VLMConfig:
    """InternVL-style: precomputed ViT patch embeddings prepended to the LM."""
    n_patches: int = 256
    patch_dim: int = 0          # 0 => already projected to d_model (stub frontend)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention window used in long-context mode (None => full causal).
    long_context_window: Optional[int] = None
    # whether the arch is sub-quadratic in sequence length (SSM / hybrid /
    # windowed attention) and therefore runs the long_500k shape.
    sub_quadratic: bool = False
    param_dtype: str = "bfloat16"
    # optimizer choice at production scale ("adamw" | "adafactor").
    optimizer: str = "adamw"
    # int8 KV cache for decode shapes (memory-bound fits, e.g. qwen1.5-32b).
    kv_cache_dtype: str = "bfloat16"
    # shard parameters over the data axis too (FSDP / ZeRO-3 style weight
    # sharding) -- required for the largest models.
    fsdp: bool = False
    # --- TP-compat head adjustments (implementation details, like vocab
    # padding; padded heads have zero weights => numerically exact) ---
    # KV-head replication for serving when n_kv_heads < TP degree (the
    # vLLM/TensorRT approach): cache stores n_kv * kv_replication heads so the
    # cache shards over the 16-way model axis.
    kv_replication: int = 1
    # pad Q / KV heads up to a 16-divisible count (qwen's 40 MHA heads -> 48,
    # arctic's 56 Q heads -> 64).
    pad_heads_to: int = 0
    pad_kv_heads_to: int = 0

    @property
    def eff_q_heads(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def eff_kv_heads(self) -> int:
        return self.pad_kv_heads_to or self.n_kv_heads

    @property
    def cache_kv_heads(self) -> int:
        return self.eff_kv_heads * self.kv_replication

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/logits shard
        cleanly over a 16-way model axis (and TPU lanes). Padded logit rows
        are masked to -1e9 in unembed (whisper: 51865 -> 52224)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_rep(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6*N*D roofline math)."""
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd = cfg.resolved_head_dim
    q = cfg.n_heads * hd
    kv = cfg.n_kv_heads * hd
    emb = v * d * (1 if cfg.tie_embeddings else 2)

    def attn_params() -> int:
        return d * q + 2 * d * kv + q * d

    def dense_ff(ff: int) -> int:
        return 3 * d * ff  # swiglu: w1, w3, w2

    per_layer = 0
    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params() + dense_ff(f) + 2 * d
    elif cfg.family == "moe":
        m = cfg.moe
        per_layer = attn_params() + m.n_experts * dense_ff(f) + 2 * d
        per_layer += d * m.n_experts  # router
        if m.dense_residual_ff:
            per_layer += dense_ff(m.dense_residual_ff)
    elif cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * d
        # mamba2 block: in_proj (x, z, B, C, dt) + out_proj + conv + norm
        nheads = d_in // s.head_dim
        mamba = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads) + d_in * d + 2 * d
        per_layer = mamba
        # shared attention every k layers (counted once if shared)
        n_attn = cfg.n_layers // cfg.hybrid.attn_every
        extra = attn_params() + dense_ff(f) + 2 * d
        return emb + cfg.n_layers * per_layer + (extra if cfg.hybrid.shared_attention else n_attn * extra)
    elif cfg.family == "ssm":
        x = cfg.xlstm
        d_in = int(x.mlstm_expand * d)
        # mLSTM: up-proj (2*d_in), qkv from d_in, gates, out-proj
        mlstm = d * 2 * d_in + d_in * 3 * d_in // max(cfg.n_heads, 1) * 0 + d_in * d
        mlstm += 3 * d_in * d_in // 1  # q,k,v projections (within up-projected space)
        mlstm += 2 * d  # norms
        slstm = d * 4 * d + int(x.slstm_proj_factor * d) * d * 2 + 2 * d
        n_s = cfg.n_layers // x.slstm_every
        return emb + (cfg.n_layers - n_s) * mlstm + n_s * slstm
    elif cfg.family == "audio":
        e = cfg.encdec
        enc_layer = attn_params() + dense_ff(f) + 2 * d
        dec_layer = 2 * attn_params() + dense_ff(f) + 3 * d  # self + cross
        return emb + e.n_enc_layers * enc_layer + cfg.n_layers * dec_layer
    return emb + cfg.n_layers * per_layer


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top_k experts count)."""
    if cfg.family != "moe":
        return param_count(cfg)
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    m = cfg.moe
    hd = cfg.resolved_head_dim
    q = cfg.n_heads * hd
    kv = cfg.n_kv_heads * hd
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer = (d * q + 2 * d * kv + q * d) + m.top_k * 3 * d * f + 2 * d + d * m.n_experts
    if m.dense_residual_ff:
        per_layer += 3 * d * m.dense_residual_ff
    return emb + L * per_layer
