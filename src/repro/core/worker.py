"""Containerized node entrypoint (the `%runscript` of the Apptainer image).

`--role head` starts a head: publishes its endpoint via the file rendezvous
(shared FS / bucket mount), serves the task protocol over TCP, and runs the
demo workload if requested. `--role worker` polls the rendezvous, HMAC-
handshakes, then pulls tasks over IP -- the paper's phases 2-4 over real
sockets. Used by the subprocess integration test and by the rendered Slurm /
K8s / GCP artifacts.

Control plane vs data plane
---------------------------

The head's TCP socket is **metadata only** in the default `p2p` data
plane: task payloads name *where* dependencies live (plus transfer
tickets authorizing the pull), results are registered by `(ref, size,
location)` while the blob stays in the producing worker's local
``NodeStore``, and workers move blobs among themselves through per-worker
**blob servers**. Aggregate data-plane bandwidth therefore scales with
the number of worker NICs instead of being capped by the head's one
socket. The legacy `relay` mode (every payload through the head) is kept
for single-node deployments and as the benchmark baseline
(``benchmarks/dataplane_bench.py``).

Control-plane ops (one HMAC-sealed JSON envelope per connection, nonce
replay protection, head TCP port):

  op           direction       request fields -> reply
  -----------  --------------  -------------------------------------------
  join         worker -> head  worker, resources, [blob_host, blob_port]
                               -> worker (assigned id), data_plane
  poll         worker -> head  worker ->
                                 p2p:   task, payload=(fn, args, kwargs),
                                        tenant, draining, deps=[{ref,
                                        size, tenant, sources=[{node,
                                        host, port, ticket}]}]
                                 relay: task, payload=(fn, args, kwargs,
                                        dep values), tenant, draining
                                 idle:  task=None, draining
                               a draining p2p worker's reply may carry
                               migrations=[{ref, size, node, host, port,
                               ticket}]: direct-push drain directives the
                               worker executes source -> destination (the
                               head PREPAREd each move and minted the
                               migrate-right ticket; no payload byte of
                               the move ever touches the head)
  result_meta  worker -> head  task, worker, size -- p2p result: the blob
                               stays in the worker's store; the head
                               records (ref, size, location) only
                               -> stored, spill (spill=True asks the
                               worker to move its copy to disk: the
                               tenant is over byte quota)
  result       worker -> head  task, worker, payload (pickled value) --
                               relay mode / backward compatibility
  error        worker -> head  task, worker, err
  leave        worker -> head  worker -- idle-exit request. Refused
                               (exit=False) while the worker still solely
                               holds hot blobs; the reply's
                               replicate=[{ref, node, host, port,
                               ticket}] assigns p2p pushes that make the
                               exit safe
  ticket       worker -> head  worker, task, object -- mid-fetch re-mint:
                               fresh ticketed sources for one dep whose
                               poll-time tickets expired while earlier
                               fat deps streamed
  tickets      worker -> head  worker, task, objects=[ids] -- the batched
                               form of `ticket`: ONE round trip re-mints
                               every dep that still needs it; the reply's
                               deps=[{ok, dep | error}] aligns 1:1 with
                               `objects`, so one expired or denied dep
                               carries its own verdict instead of
                               re-minting (or failing) the whole batch
  batch        worker -> head  worker, ops=[sub-ops] -- one wire frame and
                               one cluster-lock acquisition for a worker's
                               queued lock-bound acks (result_meta, error,
                               own-cache pushed, metric_deltas), with its
                               poll riding last; sub-ops the head must
                               serve outside the lock (poll, tickets) are
                               deferred past it. Reply replies=[...]
                               aligns 1:1 with ops; a failing sub-op
                               yields its own {ok: False} without
                               poisoning the rest of the frame
  metric_deltas worker-> head  worker, deltas={counter: +n} -- data-plane
                               counter deltas (blob serves / receives /
                               served bytes) folded into per-worker head
                               aggregates surfaced by `metrics`
  pushed       worker -> head  worker, object, node -- one replicate
                               assignment landed (or a dep cache was
                               registered); the directory adds the copy
                               (third-party claims are probed first)
  migrated     worker -> head  worker (destination), object -- the
                               result_meta of the migrate protocol: the
                               destination confirms one direct drain push
                               landed in its store; the head COMMITs the
                               owner handoff only now. A late ack whose
                               move was already aborted (or whose source
                               died) is probed and, if real, registered
                               as a recovered replica
  migrate_failed worker->head  worker (source), object, retryable, err --
                               the push could not land. Retryable
                               transport faults degrade to the old
                               head-relay copy (never to lineage while
                               the head is healthy); anything else
                               ABORTs + re-plans toward a fresh
                               destination/ticket
  drain        operator->head  worker, [deadline_s] -- eviction notice
  drain_status worker -> head  worker -> complete
  stats        any -> head     -> scheduler stats + tenant shares
  metrics      adapter -> head -> autoscaling signals incl. per-tenant
                               syndeo_tenant_dominant_share and
                               syndeo_tenant_quota_fraction, plus the
                               serving-plane gauges (syndeo_serve_requests,
                               syndeo_serve_shed, syndeo_serve_p99_ms,
                               syndeo_replica_count)

Service-actor lifecycle (the serving plane): workers host long-running
replica actors instead of one-shot functions. Lifecycle directives ride
the poll reply's `actor_ops` list (head -> worker, exactly like
`migrations`); worker-side acks and results ride the existing `batch`
frame. Resources are held by the scheduler for the actor's lifetime;
actor-hosting workers refuse the idle-exit `leave` handshake and a
drain of their node completes only after every replica exits.

  op           direction       request fields -> reply
  -----------  --------------  -------------------------------------------
  actor_create client -> head  factory, [actor, resources, tenant,
                               placement_group, bundle_index, kwargs] --
                               place a replica actor; the head queues an
                               actor_create directive for the hosting
                               worker's next poll
                               -> actor, worker, cap (actor-scoped
                               capability authorizing call/exit)
  actor_call   client -> head  actor, cap, [payload, call] -- verified
                               against the actor-scoped capability, then
                               queued as an actor_call directive
                               -> call (id to fetch the result with)
  actor_result worker -> head  worker, actor, call, value|error -- a
                               finished call, riding the batch frame
               client -> head  call (no worker field) -- fetch one
                               result -> done, value|error
  actor_exit   client -> head  actor, cap -- graceful exit request,
                               queued as a directive; the replica
                               finishes in-flight work first
               worker -> head  worker, actor -- exit ack (batch frame);
                               only now does the scheduler release the
                               actor's lifetime resource hold

Blob-server wire format (worker data plane, one request per connection):
every frame is an 8-byte big-endian length followed by the payload in
64 KiB chunks (`object_store.send_frame`/`recv_frame`). Request = one
sealed-JSON frame {op: get|put|del|has, object, requester, ticket};
"put" is followed by one raw blob frame whose sha256 the sealed header
authenticates. Reply = one sealed-JSON frame {ok, size, sha256 | error};
a successful "get" is followed by the raw blob frame. Tickets
(`security.TransferTicket`) are verified under the cluster token before
any bytes move: the MAC binds (object, source, requesting worker,
tenant, right, expiry), so a ticket cannot be relabeled, replayed by
another worker, or used after its fetch window.
"""
from __future__ import annotations

import argparse
import base64
import json
import pickle
import shutil
import socket
import socketserver
import tempfile
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.cluster import SyndeoCluster
from repro.core.metrics import (Histogram, MetricsHub, build_cluster_metrics,
                                render_dashboards, render_prometheus)
from repro.core.object_store import (NodeStore, ObjectRef, RemoteNodeStore,
                                     TCPTransport, recv_frame, send_frame)
from repro.core.rendezvous import Endpoint, FileRendezvous
from repro.core.scheduler import WorkerInfo
from repro.core.security import (Capability, NonceCache, SecurityError,
                                 TransferTicket, open_sealed, seal)
from repro.core.task_graph import TaskState


def _enc(obj: Any) -> str:
    return base64.b64encode(pickle.dumps(obj)).decode()


def _dec(blob: str) -> Any:
    return pickle.loads(base64.b64decode(blob))


def _request(host: str, port: int, token: str, msg: Dict[str, Any],
             timeout: float = 10.0,
             nonce_cache: Optional[NonceCache] = None) -> Dict[str, Any]:
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.sendall((json.dumps(seal(token, msg)) + "\n").encode())
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(1 << 20)
            if not chunk:
                break
            buf += chunk
    return open_sealed(token, json.loads(buf.decode()),
                       nonce_cache=nonce_cache)


def push_with_retry(transport, node_id: str, ref: ObjectRef, blob: bytes,
                    ticket: Optional[TransferTicket],
                    retries: int = 1) -> Tuple[Optional[Exception], bool]:
    """One direct blob push with bounded retry. Transient TCP faults
    (refused connect, reset, timeout -- OSError family) retry `retries`
    times; protocol refusals (SecurityError: bad/expired ticket; KeyError:
    server-side refusal) never do, because retrying cannot fix them.
    Returns (error, retryable): (None, False) on success; a truthy
    retryable tells the caller to degrade to the head-relay fallback
    rather than give the move up to lineage reconstruction."""
    last: Optional[Exception] = None
    for _ in range(retries + 1):
        try:
            transport.push(node_id, ref, blob, ticket)
            return None, False
        except (SecurityError, KeyError) as e:
            return e, False
        except OSError as e:
            last = e
        except Exception as e:  # noqa: BLE001 -- malformed reply etc.
            return e, False
    return last, True


def push_batch_with_retry(transport, node_id: str,
                          items: List[Tuple[ObjectRef, bytes,
                                            Optional[TransferTicket]]],
                          retries: int = 1
                          ) -> Tuple[Optional[List[Dict[str, Any]]],
                                     Optional[Exception], bool]:
    """One multi-blob push (see TCPTransport.push_batch) with the same
    bounded-retry policy as push_with_retry. Returns (verdicts, error,
    retryable): on success the per-blob verdicts aligned 1:1 with
    `items` (individual blobs may still carry ok=False -- e.g. one
    expired ticket -- without failing the frame); on a whole-frame
    failure verdicts is None and (error, retryable) classify it exactly
    like the single-push path. Retrying a frame whose first attempt
    landed is safe: the receiving store's import is idempotent."""
    last: Optional[Exception] = None
    for _ in range(retries + 1):
        try:
            return transport.push_batch(node_id, items), None, False
        except (SecurityError, KeyError) as e:
            return None, e, False
        except OSError as e:
            last = e
        except Exception as e:  # noqa: BLE001 -- malformed reply etc.
            return None, e, False
    return None, last, True


class BlobServer:
    """Per-node data-plane server: serves one NodeStore's blobs to peers.

    Every request is ticket-checked under the cluster token (see the
    module docstring's wire format). `tenant_of(object_id)` supplies the
    object's tenant when this node knows it (its own results, cached
    deps); for unknown objects the ticket's own tenant binding -- already
    cross-checked at mint time by the head -- is authoritative."""

    #: pre-auth request headers are tiny sealed JSON -- cap them well below
    #: the blob-frame limit so an unauthenticated peer cannot buffer GiBs
    MAX_HEADER_BYTES = 64 * 1024
    SOCKET_TIMEOUT_S = 30.0

    def __init__(self, store: NodeStore, token: str,
                 host: str = "127.0.0.1", port: int = 0,
                 tenant_of: Optional[Callable[[str], Optional[str]]] = None,
                 on_delete: Optional[Callable[[str], None]] = None,
                 on_migrate: Optional[Callable[[str, str], None]] = None,
                 on_migrate_many: Optional[
                     Callable[[List[Tuple[str, str]]], None]] = None):
        self.store = store
        self.token = token
        self.tenant_of = tenant_of or (lambda oid: None)
        self.on_delete = on_delete
        # called as on_migrate(object_id, tenant_id) after a put arriving
        # under a "migrate"-right ticket lands: the destination's hook to
        # send the head the metadata ack that COMMITs the move
        self.on_migrate = on_migrate
        # batched twin: on_migrate_many([(object_id, tenant_id), ...])
        # fires ONCE for all migrate-right blobs of a put_batch frame so
        # the destination can ack N moves in one control round trip;
        # when unset, on_migrate fires per blob as before
        self.on_migrate_many = on_migrate_many
        self._nonces = NonceCache()
        self.stats = {"serves": 0, "served_bytes": 0,
                      "receives": 0, "rejects": 0,
                      "batched_moves": 0}
        blob_srv = self

        class Handler(socketserver.BaseRequestHandler):
            def handle(self):
                blob_srv._handle(self.request)

        self.server = socketserver.ThreadingTCPServer((host, port), Handler,
                                                      bind_and_activate=True)
        self.server.daemon_threads = True
        self.host = host
        self.port = self.server.server_address[1]
        self._thread = threading.Thread(target=self.server.serve_forever,
                                        daemon=True,
                                        name=f"blob-{store.node_id}")
        self._thread.start()

    @property
    def endpoint(self) -> Tuple[str, int]:
        return (self.host, self.port)

    def shutdown(self):
        self.server.shutdown()
        # shutdown() only stops serve_forever; the listening socket fd
        # stays open until server_close()
        self.server.server_close()

    # -- one request ----------------------------------------------------------

    def _handle(self, sock: socket.socket):
        blob_out: Optional[bytes] = None
        try:
            sock.settimeout(self.SOCKET_TIMEOUT_S)   # a stalled peer cannot
            # pin this handler thread forever
            header = open_sealed(self.token,
                                 json.loads(recv_frame(
                                     sock, self.MAX_HEADER_BYTES).decode()),
                                 nonce_cache=self._nonces)
            blob_in = None
            put_ticket = None
            batch_tickets = None
            if header.get("op") == "put":
                # ticket verified BEFORE the blob frame is read, and the
                # read is capped at the header's declared size -- a peer
                # without a valid put ticket cannot make us buffer bytes
                try:
                    put_ticket = self._verify(header, "put")
                except Exception:
                    # the client streams the blob right behind the header;
                    # closing with the frame unread RSTs the connection,
                    # which can break the client's in-flight send AND
                    # destroy the queued error reply -- the refusal then
                    # looks like a retryable transport fault instead of a
                    # SecurityError. Drain (read and discard, bounded by
                    # the declared size) so the refusal travels back clean.
                    self._drain_frame(
                        sock, int(header.get("size", 0)) + 1024)
                    raise
                blob_in = recv_frame(
                    sock, max_bytes=int(header.get("size", 0)) + 1024)
            elif header.get("op") == "put_batch":
                # same discipline as put, per blob: EVERY declared blob's
                # ticket is verified before the multi-blob frame is read;
                # a frame where no declaration verified is drained and
                # refused wholesale -- an unauthorized peer still cannot
                # make us buffer payload bytes
                batch_tickets, total = self._verify_batch(header)
                if any(t is not None for t, _err in batch_tickets):
                    blob_in = recv_frame(sock, max_bytes=total + 1024)
                else:
                    self._drain_frame(sock, total + 1024)
            reply, blob_out = self._dispatch(header, blob_in, put_ticket,
                                             batch_tickets)
        except Exception as e:  # noqa: BLE001 -- reply, never crash the server
            self.stats["rejects"] += 1
            reply = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        try:
            send_frame(sock, json.dumps(seal(self.token, reply)).encode())
            if blob_out is not None:
                send_frame(sock, blob_out)
        except OSError:
            pass                       # peer went away mid-reply

    @staticmethod
    def _drain_frame(sock: socket.socket, max_bytes: int):
        """Best-effort read-and-discard of one frame (refused put)."""
        try:
            recv_frame(sock, max_bytes=max_bytes)
        except (OSError, ValueError):
            pass                       # peer gone or oversized: just close

    def _verify(self, header: Dict[str, Any], right: str) -> TransferTicket:
        return self._verify_entry(header, str(header.get("requester", "")),
                                  right)

    def _verify_entry(self, entry: Dict[str, Any], requester: str,
                      right: str) -> TransferTicket:
        """Ticket check for one blob declaration -- a top-level header or
        one element of a put_batch frame's "blobs" list."""
        oid = entry.get("object", "")
        ticket_wire = entry.get("ticket")
        if not ticket_wire:
            raise SecurityError(f"blob {right} without transfer ticket")
        ticket = TransferTicket.from_wire(ticket_wire)
        if right == "put" and ticket.right == "migrate":
            # a drain-move push arrives as a put under the "migrate"
            # right; the right is inside the MAC, so verifying against
            # the declared right never widens what the head granted
            right = "migrate"
        tenant = self.tenant_of(oid)
        ticket.verify(self.token, oid, self.store.node_id,
                      requester, right,
                      object_tenant=tenant if tenant is not None
                      else ticket.tenant_id)
        return ticket

    def _verify_batch(self, header: Dict[str, Any]
                      ) -> Tuple[List[Tuple[Optional[TransferTicket],
                                            Optional[str]]], int]:
        """Pre-payload ticket pass over a put_batch frame's declarations:
        per-blob (ticket, None) or (None, error) verdict seeds, plus the
        total declared payload size bounding the frame read."""
        blobs = header.get("blobs")
        if not isinstance(blobs, list) or not blobs:
            raise ValueError("put_batch without blob declarations")
        requester = str(header.get("requester", ""))
        state: List[Tuple[Optional[TransferTicket], Optional[str]]] = []
        total = 0
        for b in blobs:
            total += max(0, int(b.get("size", 0)))
            try:
                state.append((self._verify_entry(b, requester, "put"), None))
            except Exception as e:  # noqa: BLE001 -- per-blob verdict
                state.append((None, f"{type(e).__name__}: {e}"))
        return state, total

    def _dispatch(self, header: Dict[str, Any],
                  blob_in: Optional[bytes],
                  put_ticket: Optional[TransferTicket] = None,
                  batch_tickets: Optional[
                      List[Tuple[Optional[TransferTicket],
                                 Optional[str]]]] = None
                  ) -> Tuple[Dict[str, Any], Optional[bytes]]:
        import hashlib
        op = header.get("op")
        if op == "put_batch":
            # tickets already verified by _handle BEFORE the multi-blob
            # frame was read (same discipline as put); slice the payload
            # by the declared sizes and give every blob its own verdict
            return self._put_batch(header, blob_in, batch_tickets), None
        oid = str(header.get("object", ""))
        ref = ObjectRef(oid)
        if op == "get":
            self._verify(header, "get")
            blob = self.store.export_blob(ref)
            self.stats["serves"] += 1
            self.stats["served_bytes"] += len(blob)
            return ({"ok": True, "size": len(blob),
                     "sha256": hashlib.sha256(blob).hexdigest()}, blob)
        if op == "put":
            # already verified by _handle BEFORE the blob frame was read
            # (the authoritative check); no second MAC computation here
            if blob_in is None:
                raise ValueError("put without blob frame")
            if (len(blob_in) != int(header.get("size", -1))
                    or hashlib.sha256(blob_in).hexdigest()
                    != header.get("sha256")):
                raise SecurityError(f"blob integrity check failed for {oid}")
            fresh = self.store.import_blob(ref, blob_in)
            if fresh:
                # attempt-idempotent accounting: a retried push whose
                # first attempt actually landed (the reply was lost, not
                # the blob) must not count the same bytes twice
                self.stats["receives"] += 1
            if (put_ticket is not None and put_ticket.right == "migrate"
                    and self.on_migrate is not None):
                # destination-side metadata ack: the head COMMITs the
                # directory's owner handoff only on this signal
                self.on_migrate(oid, put_ticket.tenant_id)
            return ({"ok": True}, None)
        if op == "has":
            # existence is placement metadata: ticketed like a read, so a
            # tenant cannot probe where another tenant's results live
            self._verify(header, "get")
            return ({"ok": True, "has": self.store.has(ref)}, None)
        if op == "del":
            self._verify(header, "del")
            self.store.delete(ref)
            if self.on_delete is not None:
                self.on_delete(oid)    # e.g. prune the owner's tenant map
            return ({"ok": True}, None)
        raise ValueError(f"unknown blob op {op!r}")

    def _put_batch(self, header: Dict[str, Any],
                   blob_in: Optional[bytes],
                   batch_tickets: List[Tuple[Optional[TransferTicket],
                                             Optional[str]]]
                   ) -> Dict[str, Any]:
        """Land a multi-blob push frame: the payload is the declared
        blobs concatenated in header order, each integrity-checked
        against its own (size, sha256) and imported independently --
        verdicts align 1:1 with the declarations, so one refused ticket
        or corrupt slice never poisons its neighbors. Migrate-right
        blobs are acked through ONE on_migrate_many call (the batched
        `migrated` control frame) instead of one round trip each."""
        import hashlib
        blobs = header.get("blobs") or []
        results: List[Dict[str, Any]] = []
        landed_moves: List[Tuple[str, str]] = []
        off = 0
        for decl, (ticket, err) in zip(blobs, batch_tickets):
            oid = str(decl.get("object", ""))
            size = max(0, int(decl.get("size", 0)))
            chunk = (blob_in[off:off + size]
                     if blob_in is not None else b"")
            off += size
            if err is not None:
                results.append({"ok": False, "object": oid, "error": err})
                continue
            if (len(chunk) != size or hashlib.sha256(chunk).hexdigest()
                    != decl.get("sha256")):
                results.append({"ok": False, "object": oid,
                                "error": "SecurityError: blob integrity "
                                         f"check failed for {oid}"})
                continue
            fresh = self.store.import_blob(ObjectRef(oid), chunk)
            if fresh:
                self.stats["receives"] += 1
                self.stats["batched_moves"] += 1
            if ticket.right == "migrate":
                landed_moves.append((oid, ticket.tenant_id))
            results.append({"ok": True, "object": oid})
        if landed_moves:
            if self.on_migrate_many is not None:
                self.on_migrate_many(landed_moves)
            elif self.on_migrate is not None:
                for oid, tenant in landed_moves:
                    self.on_migrate(oid, tenant)
        return {"ok": True, "results": results}


class HeadServer:
    """TCP face of a SyndeoCluster (pull-based workers).

    `data_plane="p2p"` (default): workers that advertise a blob endpoint
    at join get metadata-only polls (dep locations + transfer tickets)
    and register their results by size; the head's directory gains a
    RemoteNodeStore proxy per worker so get/migrate/release keep working
    over remote primaries, and the head runs its own BlobServer so
    client-put artifacts are fetchable without relaying through the
    control socket. Workers that join without a blob endpoint -- and
    every worker when `data_plane="relay"` -- take the legacy path where
    the head resolves deps and stores results itself.

    `head_payload_bytes` counts data-plane payload bytes that transited
    the head's control socket (dep values + result pickles in relay
    mode); the CI dataplane smoke asserts it stays 0 under p2p."""

    def __init__(self, cluster: SyndeoCluster, host: str = "127.0.0.1",
                 port: int = 0, data_plane: Optional[str] = None,
                 ticket_ttl_s: float = 30.0):
        self.cluster = cluster
        self.data_plane = data_plane or getattr(cluster, "data_plane", "p2p")
        data_plane = self.data_plane
        self.ticket_ttl_s = ticket_ttl_s
        # migrate tickets live longer than fetch tickets: the directive
        # waits for the source's next poll before any byte moves
        self.migrate_ttl_s = max(ticket_ttl_s, 60.0)
        self._outbox: Dict[str, list] = {}
        self._blob_eps: Dict[str, Tuple[str, int]] = {}
        # per-worker data-plane counter aggregates fed by the piggybacked
        # metric_deltas sub-op (mutated under the cluster lock)
        self._worker_metrics: Dict[str, Dict[str, int]] = {}
        # PREPAREd drain-move directives awaiting each source worker's
        # next poll ({ref, size, node, host, port, ticket} dicts)
        self._pending_migrations: Dict[str, List[Dict[str, Any]]] = {}
        # serving plane: actor lifecycle directives awaiting each hosting
        # worker's next poll, completed call results awaiting client
        # pickup, actor ids already asked to exit (a draining host asks
        # each replica exactly once), and router-fed serving gauges
        # (requests / shed / p99_ms) surfaced by the `metrics` op
        self._actor_outbox: Dict[str, List[Dict[str, Any]]] = {}
        self._actor_results: Dict[str, Dict[str, Any]] = {}
        self._actor_exits_asked: set = set()
        self.serve_stats: Dict[str, float] = {}
        # observability hub: shares the scheduler's registry (sojourn
        # histograms land there) and folds worker-pushed histogram
        # deltas into it; every `metrics` snapshot is recorded into the
        # hub's ring-buffer time series for dashboard history
        self.metrics_hub = MetricsHub(registry=cluster.scheduler.metrics)
        # instrument cache for the delta fold: the registry lookup
        # (lock + family/key build) costs ~2x the fold itself, and the
        # hot path folds the same few histogram names every poll
        self._hist_cache: Dict[str, Any] = {}
        self.head_payload_bytes = 0
        # bounded seen-nonce set: a captured worker envelope cannot be
        # replayed inside the freshness window (it would need a fresh nonce,
        # and the nonce is under the MAC)
        self._nonces = NonceCache()
        self._blob_srv: Optional[BlobServer] = None
        if data_plane == "p2p":
            self._blob_srv = BlobServer(cluster._head_node, cluster.token,
                                        host=host,
                                        on_migrate=self._head_migrate_ack)
            # drain migrations are peer-to-peer: the head PREPAREs each
            # move and hands the source worker a push directive; only the
            # relay fallback (below) still copies through this process
            cluster.scheduler.migrate_fn = self._migrate_directive
            # a dead source's queued directives can never be delivered:
            # drop them with the worker (same wrap style as attach())
            orig_failed = cluster.scheduler.on_worker_failed

            def on_failed(worker_id, reason="failure"):
                self._pending_migrations.pop(worker_id, None)
                orig_failed(worker_id, reason)

            cluster.scheduler.on_worker_failed = on_failed
        head = self

        class Handler(socketserver.StreamRequestHandler):
            def handle(self):
                line = self.rfile.readline()
                try:
                    msg = open_sealed(cluster.token,
                                      json.loads(line.decode()),
                                      nonce_cache=head._nonces)
                    reply = head.dispatch(msg)
                except Exception as e:  # noqa: BLE001
                    reply = {"ok": False, "error": str(e)}
                self.wfile.write(
                    (json.dumps(seal(cluster.token, reply)) + "\n").encode())

        self.server = socketserver.ThreadingTCPServer((host, port), Handler,
                                                      bind_and_activate=True)
        self.server.daemon_threads = True
        self.port = self.server.server_address[1]
        self._thread = threading.Thread(target=self.server.serve_forever,
                                        daemon=True)
        self._thread.start()
        # re-publish the rendezvous with the real TCP port
        cluster.rendezvous.publish(Endpoint(host, self.port,
                                            cluster.cluster_id, cluster.token))

    # head-side handling ------------------------------------------------------

    def _migrate_directive(self, worker_id: str, ref: ObjectRef, dst: str):
        """Scheduler migrate hook for the p2p head: PREPARE the move
        (directory in-flight state + migrate-right ticket) and queue a
        push directive for the source worker's next poll. The blob then
        moves *directly* source -> destination; the destination's
        `migrated` ack COMMITs; a move that never acks is aborted and
        re-planned by the scheduler's timeout sweep. Sources without a
        blob endpoint (relay-joined workers, whose stores live in this
        process) keep the old head-side copy path."""
        c = self.cluster
        dst_ep = self._source_endpoints(dst)
        if worker_id not in self._blob_eps or dst_ep is None:
            self._migrate_relay(worker_id, ref, dst)
            return
        try:
            if not c.store.begin_move(ref, worker_id, dst):
                c.scheduler.note_migration_failed(worker_id, ref)
                return
            ticket = c.store.migrate_ticket(ref, worker_id, dst,
                                            ttl_s=self.migrate_ttl_s)
        except SecurityError:
            c.scheduler.note_migration_denied(worker_id, ref)
            return
        self._pending_migrations.setdefault(worker_id, []).append({
            "ref": ref.id, "size": ref.size, "node": dst,
            "host": dst_ep[0], "port": dst_ep[1],
            "ticket": ticket.to_wire(),
            # remaining drain budget (None = no deadline): preemption
            # notices race the notice window, so the source worker
            # batches and orders its pushes deadline-soonest-first
            "deadline_s": c.scheduler.drain_deadline_s(worker_id)})

    def _migrate_relay(self, worker_id: str, ref: ObjectRef, dst: str):
        """Head-relayed move on a background thread (the blocking
        export/import RPCs run lock-free): the pre-p2p path, kept for
        relay-joined workers and as the transient-transport *fallback* --
        strictly better than lineage reconstruction while the head is
        healthy. Bytes relayed for remote endpoints are counted against
        the head's NIC (head_relayed_bytes)."""
        c = self.cluster

        def run():
            try:
                moved = c.store.migrate(ref, worker_id, dst)
            except SecurityError:
                with c._lock:
                    c.scheduler.note_migration_denied(worker_id, ref)
                return
            except Exception:  # noqa: BLE001 -- e.g. peer unreachable
                moved = False
            if moved and (worker_id in self._blob_eps
                          or dst in self._blob_eps):
                c.store.stats["head_relayed_bytes"] += \
                    c.store.size_of(ref) or ref.size
            with c._lock:
                if moved:
                    c.scheduler.note_migrated(worker_id, ref)
                else:
                    c.scheduler.note_migration_failed(worker_id, ref)

        threading.Thread(target=run, daemon=True,
                         name=f"migrate-{ref.id[:8]}").start()

    def _head_migrate_ack(self, oid: str, tenant: str):
        """on_migrate hook of the head's own blob server: a drain push
        whose destination is the head store commits here directly (there
        is no remote worker to send the `migrated` op)."""
        c = self.cluster
        mv = c.store.move_in_flight(oid)
        if mv is None or mv[1] != "head":
            return
        src, dst = mv
        if c.store.commit_move(oid, src, dst):
            with c._lock:
                c.scheduler.note_migrated(src, ObjectRef(oid))

    def _source_endpoints(self, node_id: str) -> Optional[Tuple[str, int]]:
        if node_id in self._blob_eps:
            return self._blob_eps[node_id]
        if node_id == "head" and self._blob_srv is not None:
            return self._blob_srv.endpoint
        return None

    def _dep_meta(self, d: ObjectRef, wid: str,
                  tenant: str) -> Dict[str, Any]:
        """Metadata-only descriptor for ONE dependency: its size, tenant,
        and up to three ticketed sources ordered worker-peers first, idle
        links first. Cross-tenant deps are refused here, at mint time --
        the polling worker never learns where the bytes are. Also serves
        the `ticket` op, which re-mints mid-fetch when a long chain
        outlives the tickets batched at poll time."""
        c = self.cluster
        own = c.store.tenant_of(d.id)
        if own is not None and own != tenant:
            raise SecurityError(
                f"cross-tenant dep denied: task of tenant {tenant!r} "
                f"depends on an object of tenant {own!r}")
        locs = c.store.rank_sources(d, wid)
        sources = []
        for n in locs:
            ep = self._source_endpoints(n)
            if ep is None:
                continue
            ticket = TransferTicket.grant(
                c.token, d.id, n, wid, tenant, "get",
                ttl_s=self.ticket_ttl_s)
            sources.append({"node": n, "host": ep[0], "port": ep[1],
                            "ticket": ticket.to_wire()})
            if len(sources) >= 3:
                break
        if not sources and locs:
            # every copy sits in an endpoint-less head-process store
            # (a relay worker's node store, e.g. after a migration):
            # stage a head copy and serve it from the head blob server
            try:
                c.store.fetch("head", d)
                ep = self._source_endpoints("head")
                if ep is not None:
                    ticket = TransferTicket.grant(
                        c.token, d.id, "head", wid, tenant, "get",
                        ttl_s=self.ticket_ttl_s)
                    sources.append({"node": "head", "host": ep[0],
                                    "port": ep[1],
                                    "ticket": ticket.to_wire()})
            except KeyError:
                pass                   # no live copy: the worker reports it
        return {"ref": d.id, "size": c.store.size_of(d),
                "tenant": own or tenant, "sources": sources}

    def _deps_meta(self, task, wid: str, tenant: str) -> List[Dict[str, Any]]:
        return [self._dep_meta(d, wid, tenant) for d in task.deps]

    def _fail_task(self, tid: str, wid: str, err: str):
        c = self.cluster
        with c._lock:
            c.scheduler.on_task_failed(tid, err, worker_id=wid)
        ev = c._futures.get(tid)
        if ev:
            ev.set()

    # lock-bound sub-handlers -------------------------------------------------
    # These serve both their top-level op and the `batch` frame's inlined
    # path: everything in them is metadata work (directory + scheduler
    # bookkeeping, no data-plane I/O), so a batch may run them all under
    # ONE cluster-lock acquisition (the lock is reentrant).

    def _handle_result_meta(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """p2p result registration: the blob already lives in the worker's
        local store; the head records (ref, size, location) -- same tenant
        + quota admission as a relayed put, zero payload bytes here."""
        c = self.cluster
        tid, wid = msg["task"], msg["worker"]
        size = int(msg["size"])
        with c._lock:
            task = c.scheduler.graph.tasks.get(tid)
            tenant = task.spec.tenant_id if task else "default"
        try:
            ref, spill = c.store.record(
                wid, size, producer_task=tid, ref_id=f"obj-{tid}",
                tenant=tenant,
                capability=Capability.grant_for_tenant(
                    c.token, tenant, f"obj-{tid}", "put"))
        except Exception as e:  # noqa: BLE001 -- quota reject etc.: the
            # task must *fail visibly*, not sit RUNNING forever
            self._fail_task(tid, wid, f"{type(e).__name__}: {e}")
            return {"ok": True, "stored": False}
        with c._lock:
            c.scheduler.on_task_finished(tid, ref, worker_id=wid)
        ev = c._futures.get(tid)
        if ev:
            ev.set()
        return {"ok": True, "stored": True, "spill": spill}

    def _handle_error(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        c = self.cluster
        with c._lock:
            c.scheduler.on_task_failed(msg["task"], msg["err"],
                                       worker_id=msg.get("worker"))
        return {"ok": True}

    def _handle_metric_deltas(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Fold a worker's piggybacked metric deltas into the head's
        aggregates (dict arithmetic only; the caller holds -- or this
        runs fine under -- the cluster lock). `deltas` are counter
        deltas folded into the per-worker aggregate dicts; `hists` are
        sparse histogram bucket deltas folded into the hub registry's
        cluster-wide histogram of the same name (bounds are fixed per
        name, so the fold is a pure element-wise add)."""
        deltas = msg.get("deltas")
        if deltas:
            agg = self._worker_metrics.setdefault(
                str(msg.get("worker", "")), {})
            get = agg.get
            for k, v in deltas.items():
                agg[k] = get(k, 0) + int(v)
        hists = msg.get("hists")
        if hists:
            cache = self._hist_cache
            for name, delta in hists.items():
                if isinstance(delta, dict):
                    h = cache.get(name)
                    if h is None:
                        h = self.metrics_hub.registry.histogram(str(name))
                        cache[name] = h
                    h.apply_delta(delta)
        return {"ok": True}

    def _handle_actor_result(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Worker-side completion report for one actor call (pure dict
        work: batch frames run it under the one cluster-lock pass)."""
        self._actor_results[str(msg["call"])] = {
            "actor": msg.get("actor"), "host": msg.get("worker"),
            "value": msg.get("value"), "error": msg.get("error")}
        return {"ok": True}

    def _handle_actor_exited(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Worker-side exit ack: the replica finished its in-flight work
        and unhosted -- only now does the scheduler release the actor's
        lifetime resource hold (and a drain of the node can complete).
        Caller holds the cluster lock (top level or batch frame)."""
        aid = str(msg["actor"])
        released = self.cluster.scheduler.remove_actor(aid)
        self._actor_exits_asked.discard(aid)
        return {"ok": True, "released": released}

    def dispatch(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        op = msg.get("op")
        c = self.cluster
        if op == "join":
            wid = msg.get("worker") or f"tcp-{uuid.uuid4().hex[:6]}"
            self._outbox.setdefault(wid, [])
            plane = "relay"
            if (self.data_plane == "p2p" and msg.get("blob_port")
                    and msg.get("blob_host")):
                # p2p worker: the head holds only a metadata proxy; the
                # blobs stay on (and are served by) the worker itself
                self._blob_eps[wid] = (str(msg["blob_host"]),
                                       int(msg["blob_port"]))
                c.store.register_node(RemoteNodeStore(
                    wid, self._blob_eps[wid], c.token))
                plane = "p2p"
            else:
                store = NodeStore(wid)  # head-side store for relay workers
                c.store.register_node(store)
            with c._lock:
                c.scheduler.add_worker(
                    WorkerInfo(wid, msg.get("resources", {"cpu": 1.0})))
            return {"ok": True, "worker": wid, "data_plane": plane}
        if op == "poll":
            wid = msg["worker"]
            with c._lock:
                c.scheduler.heartbeat(wid)
                w = c.scheduler.workers.get(wid)
                draining = bool(w and w.draining)
            # PREPAREd drain-move directives ride the poll reply: the
            # source executes the pushes itself, so the head hands out
            # metadata only. Popped only for p2p workers (relay workers
            # never receive directives -- _migrate_directive routes them
            # to the head-side copy path, and popping here would drop
            # the batch on a reply path that cannot carry it); the
            # timeout clock restarts at delivery, so a slow poll does
            # not burn the push window (dict.pop is atomic; directives
            # re-queue via the abort/re-plan sweep if the worker dies)
            p2p = wid in self._blob_eps
            # popped under the cluster lock: _migrate_directive appends
            # under it, and an unlocked pop could orphan a directive
            # appended between the pop and the append's setdefault
            if p2p:
                with c._lock:
                    moves = self._pending_migrations.pop(wid, [])
            else:
                moves = []
            if moves:
                # directives whose move was aborted/re-planned since they
                # were queued (timeout sweep, destination death) are
                # dropped here instead of burning a redundant fat push
                moves = [m for m in moves
                         if c.store.move_in_flight(m["ref"])
                         == (wid, m["node"])]
            if moves:
                with c._lock:
                    for mv in moves:
                        c.scheduler.note_move_dispatched(wid, mv["ref"])
            # actor lifecycle directives ride the poll reply exactly like
            # drain moves. A draining host asks each replica to exit
            # (once): the drain completes only after every exit is acked,
            # so scale-down never cuts off an in-flight decode.
            with c._lock:
                if draining:
                    for aid in c.scheduler.actors_on(wid):
                        if aid not in self._actor_exits_asked:
                            self._actor_exits_asked.add(aid)
                            self._actor_outbox.setdefault(wid, []).append(
                                {"op": "actor_exit", "actor": aid})
                acts = self._actor_outbox.pop(wid, [])

            def with_moves(reply: Dict[str, Any]) -> Dict[str, Any]:
                if moves:
                    reply["migrations"] = moves
                if acts:
                    reply["actor_ops"] = acts
                return reply

            box = self._outbox.get(wid, [])
            if not box:
                # a drained worker with an empty queue may exit: the head
                # finishes the drain once migrations land and tasks stop
                return with_moves({"ok": True, "task": None,
                                   "draining": draining})
            tid = box.pop(0)
            with c._lock:
                task = c.scheduler.graph.tasks[tid]
                tenant = task.spec.tenant_id
            if p2p:
                try:
                    # metadata-only dispatch: control payload + dep
                    # locations/tickets; the worker pulls the bytes peer
                    # to peer. Built OUTSIDE the cluster lock -- the
                    # head-staging fallback may do a real transfer, and
                    # data-plane I/O must never stall the control plane
                    # (the store has its own lock)
                    return with_moves(
                        {"ok": True, "task": tid,
                         "payload": _enc((task.spec.fn, task.spec.args,
                                          task.spec.kwargs)),
                         "deps": self._deps_meta(task, wid, tenant),
                         "tenant": tenant, "draining": draining})
                except Exception as e:  # noqa: BLE001
                    self._fail_task(tid, wid, f"{type(e).__name__}: {e}")
                    return with_moves({"ok": True, "task": None,
                                       "draining": draining})
            with c._lock:
                try:
                    # relay: deps are resolved head-side *as the task's
                    # tenant*: a task whose deps point at another tenant's
                    # objects fails here -- as a *task failure*, not a
                    # stranded RUNNING task (the worker just keeps
                    # polling). Relay stores live in this process, so
                    # these are memory copies, safe under the lock.
                    payload = _enc(
                        (task.spec.fn, task.spec.args, task.spec.kwargs,
                         [c.store.get(
                             "head", d,
                             capability=Capability.grant_for_tenant(
                                 c.token, tenant, d.id, "get"))
                          for d in task.deps]))
                    self.head_payload_bytes += sum(
                        c.store.size_of(d) for d in task.deps)
                except Exception as e:  # noqa: BLE001
                    c.scheduler.on_task_failed(
                        tid, f"{type(e).__name__}: {e}", worker_id=wid)
                    ev = c._futures.get(tid)
                    if ev:
                        ev.set()
                    return {"ok": True, "task": None, "draining": draining}
            return {"ok": True, "task": tid, "payload": payload,
                    "tenant": tenant, "draining": draining}
        if op == "result_meta":
            return self._handle_result_meta(msg)
        if op == "result":
            tid, wid = msg["task"], msg["worker"]
            value = _dec(msg["payload"])
            with c._lock:
                task = c.scheduler.graph.tasks.get(tid)
                tenant = task.spec.tenant_id if task else "default"
            try:
                ref = c.store.put("head", value, producer_task=tid,
                                  ref_id=f"obj-{tid}", tenant=tenant)
            except Exception as e:  # noqa: BLE001 -- e.g. quota reject: the
                # task must *fail visibly*, not sit RUNNING forever
                self._fail_task(tid, wid, f"{type(e).__name__}: {e}")
                return {"ok": True, "stored": False}
            with c._lock:
                # counter writes stay under the cluster lock: handler
                # threads run concurrently and += is not atomic
                self.head_payload_bytes += ref.size
                c.scheduler.on_task_finished(tid, ref, worker_id=wid)
            ev = c._futures.get(tid)
            if ev:
                ev.set()
            return {"ok": True}
        if op == "error":
            return self._handle_error(msg)
        if op == "metric_deltas":
            with c._lock:
                return self._handle_metric_deltas(msg)
        if op == "leave":
            # idle-exit handshake: a worker may only walk away once no hot
            # object's last copy lives on it. The head hands back p2p push
            # assignments (peer blob servers, put tickets) for the at-risk
            # blobs; the worker replicates, reports `pushed`, and re-asks.
            wid = msg["worker"]
            with c._lock:
                w = c.scheduler.workers.get(wid)
                if w is None:
                    return {"ok": True, "exit": True}
                if w.running or w.actors:
                    # a live replica actor is never idle cover: the host
                    # must not walk away between request bursts
                    return {"ok": True, "exit": False, "replicate": []}
                at_risk = self._at_risk_objects(wid)
                if at_risk and wid not in self._blob_eps:
                    # relay worker: its "node store" lives in THIS process
                    # (results were relayed), so the head migrates the
                    # blobs itself -- asking the worker to push bytes it
                    # never held would refuse the exit forever
                    for ref in at_risk:
                        try:
                            c.store.migrate(ref, wid, "head")
                        except Exception:  # noqa: BLE001 -- keep refusing
                            pass
                    at_risk = self._at_risk_objects(wid)
                if not at_risk:
                    ok = c.scheduler.retire_worker(wid)
                    if ok:
                        self._outbox.pop(wid, None)
                        self._blob_eps.pop(wid, None)
                        self._pending_migrations.pop(wid, None)
                    return {"ok": True, "exit": bool(ok)}
                if wid not in self._blob_eps:
                    # relay worker whose blobs could not be migrated (e.g.
                    # a tenant-scoped guard): nothing the worker itself can
                    # push -- release it and degrade to drop + lineage,
                    # exactly like a drain would, rather than livelock
                    ok = c.scheduler.retire_worker(wid)
                    if ok:
                        self._outbox.pop(wid, None)
                    return {"ok": True, "exit": bool(ok), "replicate": []}
                moves = self._replication_plan(wid, at_risk)
            return {"ok": True, "exit": False, "replicate": moves}
        if op == "actor_create":
            # place a long-running replica actor: the scheduler acquires
            # its resources for the actor's LIFETIME (placement-group
            # aware), and the hosting worker instantiates it from the
            # actor_create directive riding its next poll reply
            aid = str(msg.get("actor") or f"actor-{uuid.uuid4().hex[:6]}")
            tenant = str(msg.get("tenant") or "default")
            factory = str(msg["factory"])
            with c._lock:
                try:
                    wid = c.scheduler.place_actor(
                        aid, msg.get("resources") or {"cpu": 1.0}, tenant,
                        msg.get("placement_group"), msg.get("bundle_index"))
                except ValueError as e:
                    return {"ok": False, "error": str(e)}
                if wid is None:
                    return {"ok": False,
                            "error": f"no worker fits actor {aid!r}"}
                self._actor_outbox.setdefault(wid, []).append(
                    {"op": "actor_create", "actor": aid, "factory": factory,
                     "kwargs": msg.get("kwargs") or {}, "tenant": tenant})
            cap = Capability.grant_actor(c.token, tenant, aid)
            return {"ok": True, "actor": aid, "worker": wid,
                    "cap": {"object_id": cap.object_id, "right": cap.right,
                            "mac": cap.mac, "tenant_id": cap.tenant_id}}
        if op == "actor_call":
            # route one request to a replica -- verified against the
            # actor-scoped capability BEFORE anything is queued
            aid = str(msg["actor"])
            with c._lock:
                info = c.scheduler.actors.get(aid)
            if info is None:
                return {"ok": False, "error": f"unknown actor {aid!r}"}
            cd = msg.get("cap") or {}
            cap = Capability(str(cd.get("object_id", "")),
                             str(cd.get("right", "")),
                             str(cd.get("mac", "")),
                             str(cd.get("tenant_id", "default")))
            try:
                cap.verify_actor(c.token, aid, info.tenant_id)
            except SecurityError as e:
                return {"ok": False, "error": str(e)}
            call_id = str(msg.get("call") or f"call-{uuid.uuid4().hex[:8]}")
            with c._lock:
                self._actor_outbox.setdefault(info.worker_id, []).append(
                    {"op": "actor_call", "actor": aid, "call": call_id,
                     "payload": msg.get("payload")})
            return {"ok": True, "call": call_id, "worker": info.worker_id}
        if op == "actor_result":
            if msg.get("worker"):      # worker-side completion report
                with c._lock:
                    return self._handle_actor_result(msg)
            res = self._actor_results.pop(str(msg["call"]), None)
            if res is None:
                return {"ok": True, "done": False}
            return dict({"ok": True, "done": True}, **res)
        if op == "actor_exit":
            aid = str(msg["actor"])
            if msg.get("worker"):      # worker-side exit ack
                with c._lock:
                    return self._handle_actor_exited(msg)
            with c._lock:
                info = c.scheduler.actors.get(aid)
            if info is None:
                return {"ok": True, "exited": True}
            cd = msg.get("cap") or {}
            cap = Capability(str(cd.get("object_id", "")),
                             str(cd.get("right", "")),
                             str(cd.get("mac", "")),
                             str(cd.get("tenant_id", "default")))
            try:
                cap.verify_actor(c.token, aid, info.tenant_id)
            except SecurityError as e:
                return {"ok": False, "error": str(e)}
            with c._lock:
                if aid not in self._actor_exits_asked:
                    self._actor_exits_asked.add(aid)
                    self._actor_outbox.setdefault(info.worker_id,
                                                  []).append(
                        {"op": "actor_exit", "actor": aid})
            return {"ok": True, "exited": False}
        if op == "ticket":
            # mid-fetch re-mint: a task with many fat deps can outlive the
            # tickets batched into its poll reply -- the worker asks for a
            # fresh descriptor per remaining dep (same tenant checks)
            wid, tid = msg["worker"], msg.get("task", "")
            with c._lock:
                task = c.scheduler.graph.tasks.get(tid)
                tenant = task.spec.tenant_id if task else None
            if tenant is None:
                return {"ok": False, "error": f"unknown task {tid!r}"}
            try:
                ref = ObjectRef(str(msg["object"]))
                return {"ok": True, "dep": self._dep_meta(ref, wid, tenant)}
            except SecurityError as e:
                return {"ok": False, "error": str(e)}
        if op == "tickets":
            # batched mid-fetch re-mint: one round trip refreshes every
            # dep the worker still needs. Each dep gets its OWN verdict
            # (aligned 1:1 with `objects`): one expired or denied dep
            # must not re-mint deps that already landed, nor fail the
            # whole batch. May stage head copies (`_dep_meta` fallback),
            # so this handler never runs under the cluster lock.
            wid, tid = msg["worker"], msg.get("task", "")
            with c._lock:
                task = c.scheduler.graph.tasks.get(tid)
                tenant = task.spec.tenant_id if task else None
            if tenant is None:
                return {"ok": False, "error": f"unknown task {tid!r}"}
            deps: List[Dict[str, Any]] = []
            for oid in msg.get("objects", []):
                try:
                    deps.append({"ok": True,
                                 "dep": self._dep_meta(
                                     ObjectRef(str(oid)), wid, tenant)})
                except Exception as e:  # noqa: BLE001 -- per-dep verdict
                    deps.append({"ok": False,
                                 "error": f"{type(e).__name__}: {e}"})
            return {"ok": True, "deps": deps}
        if op == "pushed":
            # a worker registering its OWN cache is trusted at the same
            # level as its result_meta size claims (sealed envelope, its
            # bytes, its node) -- no probe on the hot dep-cache path.
            # Third-party claims ("node X now holds it") are probed before
            # the directory (and thus drain cover) believes them.
            if msg.get("worker") == msg["node"]:
                c.store.note_replica(msg["object"], msg["node"])
                return {"ok": True}
            ok = c.store.confirm_replica(msg["object"], msg["node"])
            return {"ok": ok}
        if op == "migrated":
            # destination ack for one direct drain push -- the
            # result_meta of the migrate protocol. Only now does the head
            # COMMIT the directory's owner handoff; the commit also
            # deletes the source's copy (a control-sized `del`, zero
            # payload through the head).
            wid, oid = msg["worker"], str(msg["object"])
            mv = c.store.move_in_flight(oid)
            if mv is None:
                # the move was already aborted (timeout sweep) or its
                # source died mid-drain: a landed push is still a real
                # copy -- probe before believing (same rule as
                # third-party `pushed` claims), then wake any tasks the
                # apparent loss parked
                if c.store.confirm_replica(oid, wid):
                    with c._lock:
                        for t in c.scheduler.graph.object_available(
                                ObjectRef(oid)):
                            c.scheduler._enqueue_ready(t)
                        c.scheduler.schedule()
                    return {"ok": True, "committed": False,
                            "recovered": True}
                # the object was released mid-move: the landed copy is
                # garbage -- purge it so it does not squat in the
                # destination's store with no directory entry to GC it
                c.store.purge_copy(oid, wid)
                return {"ok": True, "committed": False}
            src, dst = mv
            if wid != dst:
                # a STALE directive's push landed somewhere the current
                # (re-planned) move no longer points: register the probed
                # copy as an ordinary replica so the bytes stay
                # directory-tracked -- and GC-able on release -- instead
                # of leaking unrecorded in the old destination's store
                replica = c.store.confirm_replica(oid, wid)
                return {"ok": True, "committed": False, "replica": replica}
            # commit OUTSIDE the cluster lock: it may issue the ticketed
            # `del` of the source's copy over TCP
            committed = c.store.commit_move(oid, src, dst)
            if committed:
                with c._lock:
                    c.scheduler.note_migrated(src, ObjectRef(oid))
            return {"ok": True, "committed": committed}
        if op == "migrate_failed":
            # source-side push failure report. Probe-first abort: a push
            # that landed right before a timed-out reply is promoted to a
            # COMMIT. A *retryable* transport fault (after the worker's
            # own bounded retry) degrades to the head-relay copy -- never
            # to lineage reconstruction while the head is healthy;
            # anything else re-plans toward a fresh destination + ticket.
            wid, oid = msg["worker"], str(msg["object"])
            mv = c.store.move_in_flight(oid)
            if mv is None or mv[0] != wid:
                return {"ok": True}
            src, dst = mv
            ref = ObjectRef(oid)
            if c.store.abort_move(oid, probe=True):
                with c._lock:
                    c.scheduler.note_migrated(src, ref)
                return {"ok": True, "committed": True}
            if msg.get("retryable"):
                c.store.stats["relay_fallbacks"] += 1
                with c._lock:
                    # the relay copy starts NOW: restart the move's
                    # timeout clock so a long transfer is not aborted
                    # against a window that began at plan time
                    c.scheduler.note_move_dispatched(src, oid)
                self._migrate_relay(src, ref, dst)
                return {"ok": True, "fallback": "relay"}
            with c._lock:
                c.scheduler.note_migration_failed(src, ref)
                c.scheduler._dispatch_moves(src)
            return {"ok": True}
        if op == "drain":
            # eviction notice for a remote worker: the outer resource
            # manager (or an operator) asks the head to retire this node
            wid = msg["worker"]
            with c._lock:
                ok = c.scheduler.begin_drain(wid, msg.get("deadline_s"))
            return {"ok": ok, "worker": wid}
        if op == "drain_status":
            wid = msg["worker"]
            with c._lock:
                complete = c.scheduler.drain_complete(wid)
                if complete:
                    c.scheduler.finish_drain(wid)
            if complete:
                # the worker exits on this reply: nothing will ever poll
                # its remaining directives out of the queue
                self._pending_migrations.pop(wid, None)
            return {"ok": True, "worker": wid, "complete": complete}
        if op == "stats":
            with c._lock:
                return {"ok": True, "stats": dict(c.scheduler.stats),
                        "tenants": c.scheduler.tenant_shares()}
        if op == "batch":
            # one wire frame, ONE cluster-lock acquisition for the
            # lock-bound sub-ops a worker queued between polls
            # (result_meta / error / own-cache pushed / metric_deltas).
            # Sub-ops that may do data-plane staging I/O (the poll riding
            # last, ticket re-mints) are deferred OUTSIDE the lock and
            # served by their normal handlers. Replies align 1:1 with
            # ops; each sub-op carries its own verdict.
            subs = msg.get("ops") or []
            replies: List[Optional[Dict[str, Any]]] = [None] * len(subs)
            deferred: List[int] = []
            with c._lock:
                for i, sub in enumerate(subs):
                    sop = sub.get("op") if isinstance(sub, dict) else None
                    try:
                        if sop == "result_meta":
                            replies[i] = self._handle_result_meta(sub)
                        elif sop == "error":
                            replies[i] = self._handle_error(sub)
                        elif sop == "metric_deltas":
                            replies[i] = self._handle_metric_deltas(sub)
                        elif (sop == "pushed"
                              and sub.get("worker") == sub.get("node")):
                            # own-cache claim: trusted without a probe
                            # (same rule as the top-level handler) --
                            # pure directory work, safe under the lock
                            c.store.note_replica(str(sub["object"]),
                                                 str(sub["node"]))
                            replies[i] = {"ok": True}
                        elif sop == "actor_result" and sub.get("worker"):
                            # a replica's finished call (dict work only)
                            replies[i] = self._handle_actor_result(sub)
                        elif sop == "actor_exit" and sub.get("worker"):
                            # a replica's exit ack: releases the actor's
                            # lifetime resource hold under this same pass
                            replies[i] = self._handle_actor_exited(sub)
                        elif sop == "batch":
                            replies[i] = {"ok": False,
                                          "error": "nested batch refused"}
                        else:
                            deferred.append(i)
                    except Exception as e:  # noqa: BLE001 -- per-sub
                        # verdict: one bad ack must not poison the frame
                        replies[i] = {"ok": False,
                                      "error": f"{type(e).__name__}: {e}"}
            for i in deferred:
                try:
                    replies[i] = self.dispatch(subs[i])
                except Exception as e:  # noqa: BLE001
                    replies[i] = {"ok": False,
                                  "error": f"{type(e).__name__}: {e}"}
            return {"ok": True, "replies": replies}
        if op == "metrics":
            # the scaling signals the K8s custom-metrics adapter republishes
            # for the HorizontalPodAutoscaler (backends/kubernetes.py), plus
            # the observability plane's counters/percentiles -- all built by
            # the ONE builder the chaos conformance checker cross-examines
            return self._build_metrics()
        if op == "metrics_text":
            # Prometheus text exposition: the same flat snapshot rendered
            # with the hub registry's histogram families (_bucket layout)
            flat = self._build_metrics()
            return {"ok": True,
                    "text": render_prometheus(self.metrics_hub.registry,
                                              flat=flat)}
        if op == "dashboards":
            return {"ok": True, "dashboards": render_dashboards()}
        return {"ok": False, "error": f"unknown op {op!r}"}

    def _build_metrics(self) -> Dict[str, Any]:
        """Snapshot scheduler-derived values under the cluster lock,
        then build the flat metrics reply outside it (store reads take
        their own shard locks) and record it into the hub's ring-buffer
        time series."""
        c = self.cluster
        with c._lock:
            workers = [w for w in c.scheduler.workers.values() if w.alive]
            busy = sum(1 for w in workers if w.running)
            backlog = sum(
                1 for t in c.scheduler.graph.tasks.values()
                if t.state in (TaskState.READY, TaskState.PENDING))
            by_tenant = c.scheduler.backlog_by_tenant()
            shares = c.scheduler.tenant_shares()
            wm = {k: dict(m) for k, m in self._worker_metrics.items()}
            replica_count = len(c.scheduler.actors)
            serve = dict(self.serve_stats)
        out = build_cluster_metrics(
            c.store, c.scheduler, worker_metrics=wm, serve_stats=serve,
            replica_count=replica_count, workers=len(workers), busy=busy,
            backlog=backlog, backlog_by_tenant=by_tenant, shares=shares)
        self.metrics_hub.ingest(time.time(), out)
        return out

    def _at_risk_objects(self, wid: str) -> List[ObjectRef]:
        """Hot objects whose only copy sits on `wid` (caller holds the
        cluster lock). Same hotness rule as the drain planner."""
        c = self.cluster
        active = (TaskState.PENDING, TaskState.READY, TaskState.RUNNING)
        hot_deps = {d.id for t in c.scheduler.graph.tasks.values()
                    if t.state in active for d in t.deps}
        return [ref for oid, ref in c.store.objects_on(wid).items()
                if c.store.sole_holder(ref, wid)
                and (c.store.refcount(oid) > 0 or oid in hot_deps)]

    def _replication_plan(self, wid: str,
                          at_risk: List[ObjectRef]) -> List[Dict[str, Any]]:
        """Push assignments for a leaving worker's at-risk blobs: each goes
        to the peer (or the head's blob server) with the least-loaded
        link, authorized by a put ticket bound to the pushing worker."""
        c = self.cluster
        peers = sorted((p for p in self._blob_eps if p != wid
                        and c.store.has_node(p)),
                       key=lambda p: (c.store.link_load(p), p))
        moves = []
        for ref in at_risk:
            dst = peers[0] if peers else "head"
            ep = self._source_endpoints(dst)
            if ep is None:
                continue               # nowhere to push: keep refusing exit
            tenant = c.store.tenant_of(ref.id) or ref.tenant
            ticket = TransferTicket.grant(c.token, ref.id, dst, wid,
                                          tenant, "put",
                                          ttl_s=max(self.ticket_ttl_s, 60.0))
            moves.append({"ref": ref.id, "node": dst,
                          "host": ep[0], "port": ep[1],
                          "ticket": ticket.to_wire()})
            if peers:
                peers.append(peers.pop(0))   # rotate: spread the pushes
        return moves

    def launch(self, task, worker_id: str):
        self._outbox.setdefault(worker_id, []).append(task.id)

    def attach(self):
        """Route scheduler launches for tcp- workers through the outbox."""
        orig = self.cluster.scheduler.launch_fn

        def launch(task, worker_id):
            if worker_id.startswith("tcp-") or worker_id in self._outbox:
                self.launch(task, worker_id)
            else:
                orig(task, worker_id)
        self.cluster.scheduler.launch_fn = launch

    def shutdown(self):
        self.server.shutdown()
        self.server.server_close()   # release the listening socket fd
        if self._blob_srv is not None:
            self._blob_srv.shutdown()


def run_worker(rendezvous_dir: str, cluster_id: str, worker_id: str = "",
               max_idle_s: float = 30.0, data_plane: str = "p2p",
               blob_host: str = "127.0.0.1",
               capacity_bytes: int = 256 << 20,
               spill_dir: Optional[str] = None,
               actor_factories: Optional[Dict[str, Callable[..., Any]]]
               = None,
               flush_metrics_on_exit: bool = True,
               metrics_every: int = 4,
               metrics_truth: Optional[Dict[str, int]] = None):
    """Worker main loop. In the default p2p data plane the worker runs a
    blob server over its local NodeStore, pulls dependencies peer-to-peer
    with head-minted transfer tickets, and registers results by metadata
    only. `data_plane="relay"` (or a head running in relay mode) falls
    back to the legacy everything-through-the-head protocol.

    Idle-exit safety: the idle clock resets on task *completion* (a long
    task must not count toward idleness), and the worker refuses to exit
    -- even past `max_idle_s` -- until the head confirms no hot object's
    last copy lives here (`leave` handshake, replicating blobs to peers
    first if needed). A worker hosting live service actors never starts
    the leave handshake at all: a replica between request bursts is not
    idle.

    `actor_factories` names the service-actor types this worker can host
    (factory name -> callable returning an object with
    ``handle(payload) -> value`` and optionally ``drain()``). Lifecycle
    directives arrive on the poll reply's `actor_ops` list; results and
    exit acks ride the next poll's batch frame.

    Observability: counter deltas (blob-server stats, spill-tier stats,
    drain-push counters) and histogram bucket deltas (poll round-trip
    latency) piggyback on the poll batch frame -- zero extra wire frames
    (the obs benchmark gates this). They accrue worker-side and ride
    every `metrics_every`-th poll (the telemetry cadence: the head folds
    1/k as often, bounding collection overhead on its hot path; nothing
    is lost in between, the deltas just wait). Deltas accrued after the
    last flush are sent in one final `metric_deltas` frame during the
    drain / leave handshake; `flush_metrics_on_exit=False` disables that
    flush (test hook -- the conformance checker must catch the loss).
    `metrics_truth`, when given, is continuously updated with this
    worker's live counter values: the ground truth the conformance
    checker holds the head's aggregates against."""
    rdv = FileRendezvous(rendezvous_dir)
    ep = rdv.wait(cluster_id, timeout=60.0)
    token = ep.token
    nonces = NonceCache()        # head replies are replay-protected too
    tenants: Dict[str, str] = {}   # object id -> tenant (blobs held here)
    # lock-bound acks queued between polls -- each entry is (op dict,
    # apply(reply) callback or None). They ride the next poll as ONE
    # `batch` frame: a result or error report no longer costs its own
    # round trip, and a transient send failure keeps them queued (the
    # head's record/stale-report guards make a replayed ack idempotent)
    pending_ops: List[Tuple[Dict[str, Any],
                            Optional[Callable[[Optional[Dict[str, Any]]],
                                              None]]]] = []
    # last blob-server counters already reported to the head: the next
    # batch carries only the deltas, advanced after a confirmed send
    metric_base: Dict[str, int] = {"serves": 0, "receives": 0,
                                   "served_bytes": 0, "batched_moves": 0,
                                   "delta_spill_bytes_saved": 0,
                                   "promotions": 0,
                                   "drain_pushed_blobs": 0,
                                   "drain_pushed_bytes": 0}
    # worker-local counters with no store/blob-server home: drain-push
    # work accrues here (between the poll that delivered the directives
    # and exit -- exactly the window the exit flush exists for)
    wstats: Dict[str, int] = {"drain_pushed_blobs": 0,
                              "drain_pushed_bytes": 0}
    # poll round-trip latency histogram: bucket deltas ride the same
    # metric_deltas sub-op; base advances only after a confirmed send
    poll_hist = Histogram()
    poll_hist_base = Histogram()
    blob_srv: Optional[BlobServer] = None
    own_spill: Optional[str] = None
    join_msg: Dict[str, Any] = {"op": "join", "worker": worker_id,
                                "resources": {"cpu": 1.0}}
    if data_plane == "p2p" and spill_dir is None:
        # relay workers never touch the local store -- only the p2p plane
        # needs a spill dir, and one we made we also clean up on exit
        spill_dir = own_spill = tempfile.mkdtemp(prefix="syndeo-blob-")
    local = NodeStore(worker_id or f"pending-{uuid.uuid4().hex[:6]}",
                      capacity_bytes=capacity_bytes, spill_dir=spill_dir)
    if data_plane == "p2p":
        blob_srv = BlobServer(local, token, host=blob_host,
                              tenant_of=tenants.get,
                              on_delete=lambda oid: tenants.pop(oid, None))
        join_msg["blob_host"] = blob_host
        join_msg["blob_port"] = blob_srv.port
    joined = _request(ep.host, ep.port, token, join_msg, nonce_cache=nonces)
    wid = joined["worker"]
    local.node_id = wid            # assigned id names the store (spill files)

    def live_metric(k: str) -> int:
        """Current ground-truth value of one piggybacked counter: spill
        keys live on the node store, drain-push keys on wstats, the rest
        on the blob server."""
        if k in ("delta_spill_bytes_saved", "promotions"):
            return int(local.stats.get(k, 0))
        if k in wstats:
            return wstats[k]
        return (int(blob_srv.stats.get(k, 0))
                if blob_srv is not None else 0)

    def compute_deltas() -> Dict[str, int]:
        if blob_srv is None:
            return {}                # relay plane: no local data plane
        return {k: live_metric(k) - metric_base[k]
                for k in metric_base if live_metric(k) != metric_base[k]}

    def update_truth():
        if metrics_truth is None:
            return
        for k in metric_base:
            metrics_truth[k] = live_metric(k)
        metrics_truth["polls"] = poll_hist.count

    def flush_metrics():
        """Exit-path flush: deltas accrued since the last confirmed poll
        (drain pushes, the final polls' latencies) would die with this
        worker -- send them as ONE final metric_deltas frame during the
        drain/leave handshake. Disabled (`flush_metrics_on_exit=False`)
        only so tests can prove the conformance checker catches the
        resulting head-vs-reality divergence."""
        update_truth()
        if not flush_metrics_on_exit:
            return
        deltas = compute_deltas()
        hd = poll_hist.to_delta(poll_hist_base)
        if not deltas and not hd["count"]:
            return
        msg: Dict[str, Any] = {"op": "metric_deltas", "worker": wid,
                               "deltas": deltas}
        if hd["count"]:
            msg["hists"] = {"syndeo_worker_poll_seconds": hd}
        try:
            _request(ep.host, ep.port, token, msg, nonce_cache=nonces)
        except Exception:  # noqa: BLE001 -- head gone: nothing left to
            return         # reconcile against anyway
        for k, v in deltas.items():
            metric_base[k] += v
        poll_hist_base.apply_delta(hd)

    def ack_migration(oid: str, tenant: str):
        """Destination-side metadata ack (the migrate protocol's
        result_meta): a drain push just landed in our local store --
        adopt its tenant and tell the head, which COMMITs the owner
        handoff. A lost ack is recovered by the head's probe-on-timeout."""
        tenants[oid] = tenant
        try:
            _request(ep.host, ep.port, token,
                     {"op": "migrated", "worker": wid, "object": oid},
                     nonce_cache=nonces)
        except Exception:  # noqa: BLE001 -- head sweep probes + commits
            pass

    def ack_migrations(landed: List[Tuple[str, str]]):
        """Batched destination-side ack: every blob of one multi-blob
        push frame that landed under a migrate-right ticket commits
        through ONE `batch` control frame of `migrated` sub-ops instead
        of one round trip each. A lost frame is recovered move-by-move
        by the head's probe-on-timeout sweep."""
        for oid, tenant in landed:
            tenants[oid] = tenant
        if len(landed) == 1:
            ack_migration(*landed[0])
            return
        ops = [{"op": "migrated", "worker": wid, "object": oid}
               for oid, _tenant in landed]
        try:
            _request(ep.host, ep.port, token,
                     {"op": "batch", "worker": wid, "ops": ops},
                     nonce_cache=nonces)
        except Exception:  # noqa: BLE001 -- head sweep probes + commits
            pass

    if blob_srv is not None:
        blob_srv.on_migrate = ack_migration
        blob_srv.on_migrate_many = ack_migrations

    def report_move_failures(failures: List[Tuple[str, bool, str]]):
        """Tell the head which moves failed -- ONE frame even for a
        whole failed batch (retryable -> relay fallback, else ABORT +
        re-plan). Losing it is safe: the timeout sweep aborts anyway."""
        if not failures:
            return
        ops = [{"op": "migrate_failed", "worker": wid, "object": oid,
                "retryable": retryable, "err": err}
               for oid, retryable, err in failures]
        req = (ops[0] if len(ops) == 1
               else {"op": "batch", "worker": wid, "ops": ops})
        try:
            _request(ep.host, ep.port, token, req, nonce_cache=nonces)
        except Exception:  # noqa: BLE001 -- the head's timeout
            pass           # sweep aborts + re-plans anyway

    def run_migrations(moves: List[Dict[str, Any]]):
        """Source-side executor for the head's direct-push drain
        directives. Moves sharing a destination coalesce into ONE
        connection carrying ONE multi-blob push frame with per-blob
        verdicts (the control plane's `batch` idiom applied to the blob
        plane): a drain plan of many small objects pays one connect +
        one ack round trip per destination instead of per object.
        Destinations are served deadline-soonest-first so a
        preemption-driven drain races its eviction notice. Success is
        acked by the *destination*; failures are reported (batched) so
        the head can fall back to the relay path (retryable) or ABORT +
        re-plan. The local copy is kept -- the head deletes it after
        COMMIT."""
        groups: Dict[Tuple[str, int, str], List[Dict[str, Any]]] = {}
        for mv in moves:
            groups.setdefault(
                (str(mv["host"]), int(mv["port"]), str(mv["node"])),
                []).append(mv)

        def urgency(grp: List[Dict[str, Any]]) -> float:
            ds = [float(mv["deadline_s"]) for mv in grp
                  if mv.get("deadline_s") is not None]
            return min(ds) if ds else float("inf")

        failures: List[Tuple[str, bool, str]] = []
        for (host, port, node), grp in sorted(
                groups.items(), key=lambda kv: urgency(kv[1])):
            transport = TCPTransport(
                lambda _n, _ep=(host, port): _ep, token, wid)
            items: List[Tuple[ObjectRef, bytes,
                              Optional[TransferTicket]]] = []
            for mv in grp:
                ref = ObjectRef(str(mv["ref"]), int(mv.get("size", 0)))
                try:
                    blob = local.export_blob(ref)
                except Exception as e:  # noqa: BLE001 -- KeyError (gone)
                    # but also e.g. an unreadable spill file: a failed
                    # export must degrade to a migrate_failed report,
                    # never kill a worker that still holds sole copies
                    # of the other drain objects
                    failures.append((ref.id, False,
                                     f"{type(e).__name__}: {e}"))
                    continue
                items.append((ref, blob,
                              TransferTicket.from_wire(mv["ticket"])))
            if not items:
                continue
            if len(items) == 1:
                ref, blob, ticket = items[0]
                err, retryable = push_with_retry(transport, node, ref,
                                                 blob, ticket)
                if err is not None:
                    failures.append((ref.id, retryable,
                                     f"{type(err).__name__}: {err}"))
                else:
                    wstats["drain_pushed_blobs"] += 1
                    wstats["drain_pushed_bytes"] += len(blob)
                continue
            verdicts, err, retryable = push_batch_with_retry(
                transport, node, items)
            if err is not None:
                failures.extend(
                    (ref.id, retryable, f"{type(err).__name__}: {err}")
                    for ref, _blob, _t in items)
                continue
            for (ref, blob, _t), v in zip(items, verdicts):
                if not v.get("ok"):
                    failures.append(
                        (ref.id, False, str(v.get("error", "refused"))))
                else:
                    wstats["drain_pushed_blobs"] += 1
                    wstats["drain_pushed_bytes"] += len(blob)
        report_move_failures(failures)

    def fetch_dep(meta: Dict[str, Any]) -> Tuple[bool, Any]:
        """One pass over a dep's ticketed sources: (True, value) when a
        fetch lands, (False, last error) when every source refused."""
        oid = meta["ref"]
        ref = ObjectRef(oid, int(meta.get("size", 0)))
        if local.has(ref):
            return True, pickle.loads(local.export_blob(ref))
        last_err: Optional[Exception] = None
        for src in meta.get("sources", []):
            try:
                ticket = (TransferTicket.from_wire(src["ticket"])
                          if src.get("ticket") else None)
                transport = TCPTransport(
                    lambda _n, _ep=(src["host"], int(src["port"])): _ep,
                    token, wid)
                blob = transport.fetch(src["node"], ref, ticket)
                local.put_blob(ref, blob)  # cache: later tasks hit local
                tenants[oid] = meta.get("tenant", "default")
                try:
                    # register the cached replica: the directory can
                    # now offer this node as a source, count it as
                    # drain cover, and -- critically -- delete it on
                    # release() (an unregistered cache would outlive
                    # its object)
                    _request(ep.host, ep.port, token,
                             {"op": "pushed", "worker": wid,
                              "object": oid, "node": wid},
                             nonce_cache=nonces)
                except OSError:
                    pass               # head unreachable: cache stays local
                return True, pickle.loads(blob)
            except Exception as e:  # noqa: BLE001 -- try the next source
                last_err = e
        return False, last_err

    def resolve_deps(metas: List[Dict[str, Any]], tid: str) -> List[Any]:
        """Fetch every dep once over its poll-time tickets, then re-mint
        ONLY the failed subset in a single batched `tickets` round trip
        and retry those. A long chain of fat deps used to cost one
        `ticket` call per expired dep; now the whole tail refreshes in
        one frame, and a dep that already landed is never re-minted."""
        values: List[Any] = [None] * len(metas)
        errors: Dict[int, Any] = {}
        for i, meta in enumerate(metas):
            ok, out = fetch_dep(meta)
            if ok:
                values[i] = out
            else:
                errors[i] = out
        if errors:
            failed = sorted(errors)
            try:
                fresh = _request(ep.host, ep.port, token,
                                 {"op": "tickets", "worker": wid,
                                  "task": tid,
                                  "objects": [metas[i]["ref"]
                                              for i in failed]},
                                 nonce_cache=nonces)
            except OSError:
                fresh = {}
            verdicts = fresh.get("deps") or []
            if fresh.get("ok") and len(verdicts) == len(failed):
                for i, verdict in zip(failed, verdicts):
                    if not verdict.get("ok"):
                        # per-dep refusal (cross-tenant, no live copy):
                        # final for THIS dep, the others keep their wins
                        errors[i] = KeyError(str(verdict.get("error")))
                        continue
                    ok, out = fetch_dep(verdict["dep"])
                    if ok:
                        values[i] = out
                        del errors[i]
                    else:
                        errors[i] = out
        if errors:
            i = min(errors)
            err = errors[i]
            if isinstance(err, Exception):
                raise err
            raise KeyError(
                f"dependency {metas[i]['ref']} has no reachable source")
        return values

    def result_meta_cb(tid: str, ref: ObjectRef):
        """Apply the head's verdict on a piggybacked result_meta ack:
        admission refusal deletes the local blob, over-quota spills it,
        and a handler-level refusal degrades to a queued error report
        (the same way a lost relay reply would have)."""
        def apply(reply: Optional[Dict[str, Any]]):
            if not isinstance(reply, dict) or not reply.get("ok", False):
                err = (reply or {}).get("error", "no reply")
                pending_ops.append((
                    {"op": "error", "task": tid, "worker": wid,
                     "err": f"result delivery failed: {err}"}, None))
                return
            if not reply.get("stored", False):
                local.delete(ref)      # admission failed head-side
                tenants.pop(ref.id, None)
            elif reply.get("spill"):
                local.spill(ref)   # over byte quota: degrade self to disk
        return apply

    def run_task(tid: str, got: Dict[str, Any]):
        try:
            if "deps" in got:          # p2p: control payload + dep metadata
                fn, args, kwargs = _dec(got["payload"])
                deps = resolve_deps(got["deps"], tid)
            else:                      # relay: dep values ride the payload
                fn, args, kwargs, deps = _dec(got["payload"])
            out = fn(*args, *deps, **kwargs)
        except Exception as e:  # noqa: BLE001 -- queued, not sent: the
            # report rides the next poll's batch frame, and an unreachable
            # head can no longer kill the worker mid-report
            pending_ops.append((
                {"op": "error", "task": tid, "worker": wid,
                 "err": f"{type(e).__name__}: {e}"}, None))
            return
        if "deps" in got and blob_srv is not None:
            # result stays local: the head records metadata only, and the
            # registration itself is QUEUED -- it piggybacks on the next
            # poll as a batch sub-op instead of costing a round trip
            ref = ObjectRef(f"obj-{tid}")
            blob = pickle.dumps(out, protocol=pickle.HIGHEST_PROTOCOL)
            local.put_blob(ref, blob)
            tenants[ref.id] = got.get("tenant", "default")
            pending_ops.append((
                {"op": "result_meta", "task": tid, "worker": wid,
                 "size": len(blob)}, result_meta_cb(tid, ref)))
            return
        try:
            _request(ep.host, ep.port, token,
                     {"op": "result", "task": tid, "worker": wid,
                      "payload": _enc(out)}, nonce_cache=nonces)
        except Exception as e:  # noqa: BLE001 -- reporting must never kill
            # the worker: a truncated reply (JSONDecodeError), a stale
            # envelope (SecurityError) or an unreachable head all degrade
            # to a queued error report + requeue-via-heartbeat, and our
            # local blobs survive for the leave/drain handshake
            pending_ops.append((
                {"op": "error", "task": tid, "worker": wid,
                 "err": f"result delivery failed: "
                        f"{type(e).__name__}: {e}"}, None))
            return

    actors: Dict[str, Any] = {}    # hosted service actors (id -> instance)

    def handle_actor_op(d: Dict[str, Any]):
        """Execute one head-queued actor lifecycle directive. Every
        outcome is acked through `pending_ops` (the next poll's batch
        frame): a create that cannot be satisfied acks an immediate
        exit so the head releases the lifetime resource hold instead of
        leaking it against a phantom replica."""
        aop = d.get("op")
        aid = str(d.get("actor"))
        if aop == "actor_create":
            factory = (actor_factories or {}).get(str(d.get("factory")))
            try:
                if factory is None:
                    raise KeyError(f"no actor factory {d.get('factory')!r}")
                actors[aid] = factory(**(d.get("kwargs") or {}))
            except Exception:  # noqa: BLE001 -- unknown factory / bad
                # kwargs: unhost immediately, the head-side registration
                # must not outlive the failed instantiation
                pending_ops.append((
                    {"op": "actor_exit", "worker": wid, "actor": aid},
                    None))
            return
        if aop == "actor_call":
            call_id = str(d.get("call"))
            inst = actors.get(aid)
            if inst is None:
                pending_ops.append((
                    {"op": "actor_result", "worker": wid, "actor": aid,
                     "call": call_id,
                     "error": f"actor {aid!r} is not hosted here"}, None))
                return
            try:
                payload = (_dec(d["payload"])
                           if d.get("payload") is not None else None)
                value = inst.handle(payload)
                pending_ops.append((
                    {"op": "actor_result", "worker": wid, "actor": aid,
                     "call": call_id, "value": _enc(value)}, None))
            except Exception as e:  # noqa: BLE001 -- per-call verdict
                pending_ops.append((
                    {"op": "actor_result", "worker": wid, "actor": aid,
                     "call": call_id,
                     "error": f"{type(e).__name__}: {e}"}, None))
            return
        if aop == "actor_exit":
            inst = actors.pop(aid, None)
            if inst is not None and hasattr(inst, "drain"):
                try:
                    inst.drain()       # finish in-flight decodes first
                except Exception:  # noqa: BLE001 -- exit anyway
                    pass
            pending_ops.append((
                {"op": "actor_exit", "worker": wid, "actor": aid}, None))

    def safe_to_leave() -> bool:
        """Idle-exit handshake: replicate solely-held hot blobs to the
        head's push assignments until the head confirms the exit."""
        failures = 0
        for _ in range(50):            # bounded: a wedged peer set cannot
            try:                       # spin the worker forever
                left = _request(ep.host, ep.port, token,
                                {"op": "leave", "worker": wid},
                                nonce_cache=nonces)
            except Exception:  # noqa: BLE001
                # one refused connect must NOT bypass the sole-copy
                # handshake -- only a persistently unreachable head
                # (cluster gone) releases the worker
                failures += 1
                if failures >= 5:
                    return True
                time.sleep(0.2)
                continue
            failures = 0
            if left.get("exit", True):
                return True
            moves = left.get("replicate", [])
            if not moves:
                return False           # busy again: keep serving
            for mv in moves:
                ref = ObjectRef(mv["ref"])
                try:
                    blob = local.export_blob(ref)
                    transport = TCPTransport(
                        lambda _n, _ep=(mv["host"], int(mv["port"])): _ep,
                        token, wid)
                    transport.push(mv["node"], ref, blob,
                                   TransferTicket.from_wire(mv["ticket"]))
                    _request(ep.host, ep.port, token,
                             {"op": "pushed", "worker": wid,
                              "object": ref.id, "node": mv["node"]},
                             nonce_cache=nonces)
                except Exception:  # noqa: BLE001 -- re-planned next round
                    pass
            time.sleep(0.02)
        return False

    try:
        idle_since = time.monotonic()
        poll_failures = 0
        polls_since_metrics = 0
        while True:
            if time.monotonic() - idle_since >= max_idle_s:
                if actors:
                    # hosting a live replica: excluded from the idle-exit
                    # clock entirely -- a request-burst gap longer than
                    # max_idle_s must not trigger the leave handshake
                    idle_since = time.monotonic()
                elif safe_to_leave():
                    flush_metrics()
                    return
                else:
                    idle_since = time.monotonic()  # still needed: serve on
            # spill-tier counters accrue on the node store, drain-push
            # counters on wstats, the rest on the blob server; all ride
            # the same delta frame, with the poll-latency histogram's
            # sparse bucket deltas alongside
            deltas = compute_deltas()
            hist_delta = poll_hist.to_delta(poll_hist_base)
            sent = list(pending_ops)
            # telemetry cadence: deltas keep accruing worker-side and
            # ride every `metrics_every`-th poll -- the frames in
            # between stay exactly as small as an unmonitored worker's
            flush_due = (polls_since_metrics + 1 >= max(metrics_every, 1)
                         and bool(deltas or hist_delta["count"]))
            if sent or flush_due:
                # piggyback everything queued since the last poll on ONE
                # batch frame, the poll itself riding last
                ops = [o for o, _ in sent]
                if flush_due:
                    sub: Dict[str, Any] = {"op": "metric_deltas",
                                           "worker": wid, "deltas": deltas}
                    if hist_delta["count"]:
                        sub["hists"] = {
                            "syndeo_worker_poll_seconds": hist_delta}
                    ops.append(sub)
                ops.append({"op": "poll", "worker": wid})
                req: Dict[str, Any] = {"op": "batch", "worker": wid,
                                       "ops": ops}
            else:
                req = {"op": "poll", "worker": wid}
            try:
                poll_t0 = time.monotonic()
                got = _request(ep.host, ep.port, token, req,
                               nonce_cache=nonces)
                # observed AFTER the frame was built: this round trip's
                # latency rides the NEXT frame (or the exit flush)
                poll_hist.observe(time.monotonic() - poll_t0)
            except OSError:
                # same tolerance as the leave handshake: one refused
                # connect (listen-backlog burst, transient timeout) must
                # not kill a worker that may hold sole copies -- only a
                # persistently unreachable head means the cluster is over.
                # Queued acks stay queued (and deltas un-advanced): they
                # replay on the next attempt.
                poll_failures += 1
                if poll_failures >= 5:
                    return
                time.sleep(0.2)
                continue
            poll_failures = 0
            polls_since_metrics += 1
            if sent or flush_due:
                replies = got.get("replies") or []
                del pending_ops[:len(sent)]
                if flush_due:
                    for k in metric_base:
                        metric_base[k] += deltas.get(k, 0)
                    poll_hist_base.apply_delta(hist_delta)
                    polls_since_metrics = 0
                update_truth()
                for (_op, cb), reply in zip(sent, replies[:len(sent)]):
                    if cb is not None:
                        cb(reply)      # may queue follow-up error reports
                got = replies[-1] if replies else {}
            if got.get("migrations"):
                # drain-move directives ride the poll reply: push the
                # blobs peer to peer before anything else -- the drain
                # cannot finish until these land (or fail and re-plan)
                run_migrations(got["migrations"])
            for directive in got.get("actor_ops") or []:
                handle_actor_op(directive)
            tid = got.get("task")
            if tid is None:
                if got.get("draining"):
                    # exit only when the head confirms the drain finished --
                    # a cancelled drain (backlog returned) keeps us serving
                    try:
                        status = _request(ep.host, ep.port, token,
                                          {"op": "drain_status",
                                           "worker": wid},
                                          nonce_cache=nonces)
                    except OSError:
                        status = {}    # transient: re-ask on the next poll
                    if status.get("complete"):
                        # the drain handshake's last act: deltas accrued
                        # since the final poll (the drain pushes above,
                        # the last polls' latencies) must not die with us
                        flush_metrics()
                        return
                time.sleep(0.05)
                continue
            run_task(tid, got)
            # the idle clock starts *after* completion: a long task's next
            # empty poll must not read as max_idle_s of idleness
            idle_since = time.monotonic()
    finally:
        update_truth()         # post-mortem ground truth for the checker
        if blob_srv is not None:
            blob_srv.shutdown()
        if own_spill is not None:
            shutil.rmtree(own_spill, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=["head", "worker"], required=True)
    ap.add_argument("--rendezvous", required=True)
    ap.add_argument("--cluster-id", required=True)
    ap.add_argument("--worker-id", default="")
    ap.add_argument("--max-idle-s", type=float, default=30.0)
    ap.add_argument("--data-plane", choices=["p2p", "relay"], default="p2p")
    ap.add_argument("--blob-host", default="127.0.0.1",
                    help="address this worker's blob server advertises to "
                         "peers -- on multi-machine fabrics pass the node's "
                         "reachable IP (e.g. $(hostname -i))")
    args = ap.parse_args()
    if args.role == "worker":
        run_worker(args.rendezvous, args.cluster_id, args.worker_id,
                   args.max_idle_s, data_plane=args.data_plane,
                   blob_host=args.blob_host)
    else:
        rdv = FileRendezvous(args.rendezvous)
        cluster = SyndeoCluster(rendezvous=rdv)
        cluster.cluster_id = args.cluster_id
        server = HeadServer(cluster, data_plane=args.data_plane)
        server.attach()
        print(f"head up on port {server.port}", flush=True)
        try:
            while True:
                time.sleep(1.0)
                cluster.health_check()
        except KeyboardInterrupt:
            server.shutdown()
            cluster.shutdown()


if __name__ == "__main__":
    main()
