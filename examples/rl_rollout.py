"""The paper's experiment, end to end: RL rollout actors (pure-JAX envs +
MLP policies) collected through the Syndeo scheduler, with throughput
reported per worker count -- plus the virtual-time replica of the full
868-CPU sweep.

    PYTHONPATH=src:. python examples/rl_rollout.py [--env Cartpole]
"""
import argparse
import sys

from repro.core import SyndeoCluster
from repro.rl.rollout import run_benchmark_local


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="Cartpole")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=500)
    args = ap.parse_args()

    # real rollouts on the threaded local backend (1 CPU -> modest numbers;
    # the scheduler/object-store path is identical to a multi-node run)
    with SyndeoCluster() as c:
        for _ in range(args.workers):
            c.add_worker()
        tput, stats = run_benchmark_local(c, args.env, args.workers,
                                          args.steps)
        print(f"[local] {args.env}: {tput:,.0f} interactions/s over "
              f"{stats['n_tasks']} actors ({stats['wall_s']:.2f}s wall)")
        print(f"[local] object-store transfers: {c.store.stats}")

    # paper-scale sweep under virtual time (Tables I/II)
    try:
        from benchmarks.paper_tables import CPU_CONFIGS, run_env_config
        print(f"\n[paper-scale sim] {args.env}:")
        base = None
        for n in CPU_CONFIGS:
            tput = run_env_config(args.env, n, seed=0)
            base = base or tput
            ideal = n / CPU_CONFIGS[0]
            print(f"  {n:4d} CPUs: {tput:9,.0f} inter/s  "
                  f"speedup {tput / base:5.1f}x (ideal {ideal:.0f}x)  "
                  f"eff {min(100, 100 * tput / base / ideal):3.0f}%")
    except ImportError:
        print("(run with PYTHONPATH=src:. to include the paper-scale sim)")


if __name__ == "__main__":
    main()
