"""Decentralized data plane benchmark: peer-to-peer vs head-relay.

The seed runtime relayed every dependency blob and task result through the
head's single socket, so aggregate data-plane bandwidth was capped by one
NIC -- the control/data-plane conflation that collapses network-bound
scaling (paper Table II's Humanoid row). The refactored stack splits a
metadata-only head directory from a worker-side blob plane; this benchmark
measures exactly that split on the REAL Scheduler/ObjectStore code under
the sim's per-link cost model:

1. *Shuffle*: N producers each emit one fat object; M consumers each
   depend on all N outputs (N x M x size of dep traffic). Under
   `data_plane="relay"` every byte serializes on the head link; under
   `"p2p"` transfers overlap across worker NICs. Reported per worker
   count: makespan, head-relayed payload bytes (p2p must be ~0, relay
   ~everything), and aggregate dep traffic.

2. *Drain*: a worker solely holding fat hot objects is drained while the
   survivors' stores are nearly too small. The bandwidth-aware planner
   (scheduler._dispatch_moves) must land every object without overflowing
   any destination store and spread the moves across links instead of
   convoying behind one survivor.

Run:  PYTHONPATH=src python benchmarks/dataplane_bench.py [--quick]
      PYTHONPATH=src python benchmarks/dataplane_bench.py --dataplane-smoke
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.core import (ObjectRef, SchedulerConfig, SimCluster, SimCostModel,
                        TaskSpec)

MB = 1_000_000


# ------------------------------------------------------------------- shuffle


def _noop():
    return None


def shuffle_run(data_plane: str, n_workers: int, n_producers: int,
                n_consumers: int, obj_bytes: int,
                bandwidth_Bps: float = 1.0e9) -> Dict[str, float]:
    """One shuffle wave under the given data plane; returns the metrics."""
    cost = SimCostModel(
        task_time_s=lambda s: 0.02,
        result_bytes=lambda s: float(obj_bytes) if s.group == "produce"
        else 1024.0,
        jitter=0.0,
        head_bandwidth_Bps=bandwidth_Bps,
        node_bandwidth_Bps=bandwidth_Bps,
        data_plane=data_plane,
        result_location="worker" if data_plane == "p2p" else "head")
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    sim.add_workers(n_workers)
    t0 = sim.now
    producers = [sim.submit(TaskSpec(fn=_noop, name=f"p{i}", group="produce"))
                 for i in range(n_producers)]
    sim.run()
    outputs: List[ObjectRef] = []
    for p in producers:
        task = sim.scheduler.graph.tasks[p.id]
        assert task.output is not None, f"producer {p.id} did not finish"
        outputs.append(task.output)
    consumers = [sim.submit(TaskSpec(fn=_noop, name=f"c{i}", group="consume"),
                            deps=list(outputs))
                 for i in range(n_consumers)]
    sim.run()
    for cns in consumers:
        assert sim.scheduler.graph.tasks[cns.id].output is not None
    dep_traffic = float(n_consumers) * sum(o.size for o in outputs)
    return {"makespan_s": sim.now - t0,
            "head_relayed_bytes": float(
                sim.store.stats["head_relayed_bytes"]),
            "dep_traffic_bytes": dep_traffic}


def bench_shuffle(worker_counts: List[int], obj_bytes: int) -> List[Dict]:
    rows = []
    for n in worker_counts:
        relay = shuffle_run("relay", n, n, n, obj_bytes)
        p2p = shuffle_run("p2p", n, n, n, obj_bytes)
        rows.append({"workers": n, "relay": relay, "p2p": p2p})
    return rows


def print_shuffle(rows: List[Dict]):
    print("\n== shuffle (N producers x N consumers, fat objects) ==")
    print(f"{'workers':>8} {'relay s':>9} {'p2p s':>9} {'speedup':>8} "
          f"{'relay head MB':>14} {'p2p head MB':>12}")
    for r in rows:
        speed = r["relay"]["makespan_s"] / max(r["p2p"]["makespan_s"], 1e-12)
        print(f"{r['workers']:>8} {r['relay']['makespan_s']:>9.3f} "
              f"{r['p2p']['makespan_s']:>9.3f} {speed:>7.1f}x "
              f"{r['relay']['head_relayed_bytes'] / MB:>14.1f} "
              f"{r['p2p']['head_relayed_bytes'] / MB:>12.1f}")


# --------------------------------------------------------------------- drain


def drain_run(n_objects: int = 8, obj_bytes: int = 8 * MB,
              n_survivors: int = 4,
              survivor_capacity: int = 24 * MB) -> Dict[str, object]:
    """Drain a worker solely holding `n_objects` fat hot objects while the
    survivors can each take only a few -- the bandwidth-aware planner must
    pack under capacity and spread across links."""
    cost = SimCostModel(task_time_s=lambda s: 0.01, jitter=0.0,
                        data_plane="p2p", result_location="worker",
                        migration_bandwidth_Bps=1.0e9)
    sim = SimCluster(cost, SchedulerConfig(enable_speculation=False,
                                           heartbeat_timeout=1e9))
    victim = sim.add_workers(1, capacity_bytes=1 << 30)[0]
    survivors = sim.add_workers(n_survivors,
                                capacity_bytes=survivor_capacity)
    refs = [sim.store.put(victim, bytearray(obj_bytes))
            for _ in range(n_objects)]     # refcount 1 each: hot
    t0 = sim.now
    sim.drain_worker_at(victim, t=0.0)
    sim.run()
    assert victim not in sim.scheduler.workers, "drain did not finish"
    dests = {}
    for r in refs:
        locs = sim.store.locations(r)
        assert locs, f"hot object {r.id} lost by the drain"
        for n in locs:
            dests[n] = dests.get(n, 0) + r.size
    over = {n: (used, sim.store._nodes[n].capacity)
            for n, used in dests.items()
            if n in survivors
            and sim.store._nodes[n].used_bytes
            > sim.store._nodes[n].capacity}
    return {"drain_s": sim.now - t0,
            "destinations": sorted(d for d in dests if d != victim),
            "bytes_by_destination": dests,
            "over_capacity": over,
            "reconstructions": sim.store.stats["reconstructions"],
            "migrated": sim.store.stats["migrations"]}


def print_drain(res: Dict[str, object]):
    print("\n== bandwidth-aware drain (fat objects, tight survivors) ==")
    print(f"  drain latency      : {res['drain_s']:.3f} s (virtual)")
    print(f"  migrations         : {res['migrated']}")
    print(f"  destinations used  : {len(res['destinations'])} "
          f"({', '.join(res['destinations'])})")
    for n, b in sorted(res["bytes_by_destination"].items()):
        print(f"    {n:>6}: {b / MB:.1f} MB")
    print(f"  over-capacity dests: {res['over_capacity'] or 'none'}")
    print(f"  reconstructions    : {res['reconstructions']}")


# --------------------------------------------------------------------- smoke


def smoke() -> int:
    """CI gate: p2p moves zero payload bytes through the head, beats relay
    on the shuffle at >= 4 workers, and the drain planner respects
    destination capacity while spreading moves."""
    rows = bench_shuffle([4, 8], obj_bytes=4 * MB)
    print_shuffle(rows)
    ok = True
    for r in rows:
        relay, p2p = r["relay"], r["p2p"]
        if p2p["head_relayed_bytes"] != 0:
            print(f"FAIL: p2p relayed {p2p['head_relayed_bytes']} bytes "
                  f"through the head at {r['workers']} workers")
            ok = False
        if relay["head_relayed_bytes"] < 0.95 * relay["dep_traffic_bytes"]:
            print(f"FAIL: relay should push ~all dep traffic through the "
                  f"head ({relay['head_relayed_bytes']:.0f} of "
                  f"{relay['dep_traffic_bytes']:.0f})")
            ok = False
        if p2p["makespan_s"] >= relay["makespan_s"]:
            print(f"FAIL: p2p not faster than relay at {r['workers']} "
                  f"workers ({p2p['makespan_s']:.3f} vs "
                  f"{relay['makespan_s']:.3f})")
            ok = False
    res = drain_run()
    print_drain(res)
    if res["over_capacity"]:
        print(f"FAIL: drain overflowed destinations: {res['over_capacity']}")
        ok = False
    if len(res["destinations"]) < 2:
        print("FAIL: drain convoyed onto a single destination")
        ok = False
    if res["reconstructions"]:
        print("FAIL: drain cost lineage reconstructions")
        ok = False
    print("\ndataplane smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dataplane-smoke", action="store_true")
    args = ap.parse_args()
    if args.dataplane_smoke:
        raise SystemExit(smoke())
    counts = [2, 4, 8] if args.quick else [2, 4, 8, 16, 32]
    rows = bench_shuffle(counts, obj_bytes=4 * MB)
    print_shuffle(rows)
    print_drain(drain_run())


if __name__ == "__main__":
    main()
