"""xLSTM (arXiv:2405.04517): mLSTM blocks (matrix memory, covariance update,
exponential gating) with a periodic sLSTM block (scalar memory, block-diagonal
recurrence). 7:1 ratio per config.

mLSTM training uses the *chunkwise-parallel* form (stabilized with the
running max-state m), because the recurrent form would have to checkpoint a
(B, H, Dh, Dh) matrix per timestep. sLSTM is inherently sequential (its
recurrence passes through the hidden state) and is computed with a scan over
time. Both have O(1)-state decode updates -> long_500k runs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.axes import constrain

F32 = jnp.float32
NEG = -1e30


def _mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.xlstm.mlstm_expand * cfg.d_model)
    H = cfg.n_heads
    return d_in, H, d_in // H


# ----------------------------------------------------------------------------
# mLSTM block
# ----------------------------------------------------------------------------

def init_mlstm_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in, H, Dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    stdi = d_in ** -0.5
    return {
        "ln": jnp.ones((d,), dtype),
        "w_up": (jax.random.normal(ks[0], (d, 2 * d_in)) * std).astype(dtype),
        "w_q": (jax.random.normal(ks[1], (d_in, d_in)) * stdi).astype(dtype),
        "w_k": (jax.random.normal(ks[2], (d_in, d_in)) * stdi).astype(dtype),
        "w_v": (jax.random.normal(ks[3], (d_in, d_in)) * stdi).astype(dtype),
        "w_if": (jax.random.normal(ks[4], (d_in, 2 * H)) * stdi).astype(F32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(F32),
        "norm_w": jnp.ones((d_in,), dtype),
        "w_down": (jax.random.normal(ks[5], (d_in, d)) * stdi).astype(dtype),
    }


def _mlstm_chunked(q, k, v, ig, lf, chunk: int):
    """Stabilized chunkwise mLSTM.

    q/k/v: (B, T, H, Dh); ig: (B, T, H) input-gate preact; lf: (B, T, H)
    log-sigmoid forget preact. Returns h (B, T, H, Dh).
    """
    B, T, H, Dh = q.shape
    nc = T // chunk
    assert T % chunk == 0
    scale = Dh ** -0.5

    qr = (q.reshape(B, nc, chunk, H, Dh).astype(F32)) * scale
    kr = k.reshape(B, nc, chunk, H, Dh).astype(F32)
    vr = v.reshape(B, nc, chunk, H, Dh).astype(F32)
    igr = ig.reshape(B, nc, chunk, H).astype(F32)
    lfr = lf.reshape(B, nc, chunk, H).astype(F32)

    b = jnp.cumsum(lfr, axis=2)               # within-chunk log decay (B,nc,Q,H)
    b_end = b[:, :, -1]                       # (B,nc,H)

    # intra-chunk log weights: D[t,s] = b_t - b_s + i_s  (s <= t)
    bq = b.transpose(0, 1, 3, 2)              # (B,nc,H,Q)
    Dlog = bq[..., :, None] - bq[..., None, :] + igr.transpose(0, 1, 3, 2)[..., None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Dlog = jnp.where(mask, Dlog, NEG)
    m_intra = jnp.max(Dlog, axis=-1)          # (B,nc,H,Q)

    def scan_body(carry, xs):
        C, n, m = carry                        # C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)
        qc, kc, vc, igc, bc, b_end_c, Dlog_c, m_intra_c = xs
        # qc (B,Q,H,Dh) ... Dlog_c (B,H,Q,Q), m_intra_c (B,H,Q)
        g = bc.transpose(0, 2, 1) + m[:, :, None]          # (B,H,Q) inter stabilizer
        m_new = jnp.maximum(m_intra_c, g)                   # (B,H,Q)
        w_intra = jnp.exp(Dlog_c - m_new[..., None])        # (B,H,Q,S)
        e_inter = jnp.exp(g - m_new)                        # (B,H,Q)

        s_qk = jnp.einsum("bqhd,bshd->bhqs", qc, kc)
        num = jnp.einsum("bhqs,bshd->bqhd", w_intra * s_qk, vc) \
            + jnp.einsum("bqhd,bhde->bqhe", qc, C) * e_inter.transpose(0, 2, 1)[..., None]
        den = jnp.einsum("bhqs,bshd,bqhd->bhq", w_intra, kc, qc) \
            + jnp.einsum("bqhd,bhd->bhq", qc, n) * e_inter
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))    # (B,H,Q)
        h = num / den.transpose(0, 2, 1)[..., None]         # (B,Q,H,Dh)

        # state update to chunk end (re-stabilized against the new max-state)
        m_state_new = jnp.maximum(b_end_c + m, jnp.max((b_end_c[:, None, :] - bc) + igc, axis=1))
        decay_old = jnp.exp(b_end_c + m - m_state_new)      # (B,H)
        w_state = jnp.exp((b_end_c[:, None, :] - bc) + igc - m_state_new[:, None, :])
        C_new = decay_old[:, :, None, None] * C + jnp.einsum("bsh,bshd,bshe->bhde", w_state, kc, vc)
        n_new = decay_old[:, :, None] * n + jnp.einsum("bsh,bshd->bhd", w_state, kc)
        return (C_new, n_new, m_state_new), h

    C0 = jnp.zeros((B, H, Dh, Dh), F32)
    n0 = jnp.zeros((B, H, Dh), F32)
    m0 = jnp.full((B, H), -30.0, F32)  # effectively "empty" stabilizer
    xs = tuple(a.transpose(1, 0, *range(2, a.ndim)) for a in
               (qr, kr, vr, igr, b, b_end, Dlog.transpose(0, 1, 2, 3, 4), m_intra))
    (_, _, _), hs = jax.lax.scan(scan_body, (C0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, Dh)


def mlstm_fwd(p, x, cfg: ModelConfig, chunk: int = 256):
    d_in, H, Dh = _mlstm_dims(cfg)
    B, T, _ = x.shape
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", xn, p["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    xi = constrain(xi, "batch", None, "model")
    q = jnp.einsum("bte,ef->btf", xi, p["w_q"]).reshape(B, T, H, Dh)
    k = jnp.einsum("bte,ef->btf", xi, p["w_k"]).reshape(B, T, H, Dh)
    v = jnp.einsum("bte,ef->btf", xi, p["w_v"]).reshape(B, T, H, Dh)
    gif = jnp.einsum("bte,eh->bth", xi.astype(F32), p["w_if"]) + p["b_if"]
    ig, fg = jnp.split(gif, 2, axis=-1)
    lf = jax.nn.log_sigmoid(fg)

    chunk = min(chunk, T)
    h = _mlstm_chunked(q, k, v, ig, lf, chunk)
    h = h.reshape(B, T, d_in).astype(x.dtype)
    h = L.rms_norm(h * jax.nn.silu(z.astype(F32)).astype(z.dtype), p["norm_w"], cfg.norm_eps)
    return x + jnp.einsum("bte,ed->btd", h, p["w_down"])


def mlstm_decode(p, x, state, cfg: ModelConfig):
    """O(1) recurrent mLSTM step. state = (C, n, m)."""
    d_in, H, Dh = _mlstm_dims(cfg)
    B = x.shape[0]
    C, n, m = state
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", xn, p["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bte,ef->btf", xi, p["w_q"]).reshape(B, H, Dh).astype(F32) * (Dh ** -0.5)
    k = jnp.einsum("bte,ef->btf", xi, p["w_k"]).reshape(B, H, Dh).astype(F32)
    v = jnp.einsum("bte,ef->btf", xi, p["w_v"]).reshape(B, H, Dh).astype(F32)
    gif = jnp.einsum("bte,eh->bth", xi.astype(F32), p["w_if"])[:, 0] + p["b_if"]
    ig, fg = jnp.split(gif, 2, axis=-1)
    lf = jax.nn.log_sigmoid(fg)                            # (B,H)

    m_new = jnp.maximum(lf + m, ig)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(ig - m_new)
    C = fp[:, :, None, None] * C + ip[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n = fp[:, :, None] * n + ip[:, :, None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / den[:, :, None]).reshape(B, 1, d_in).astype(x.dtype)
    h = L.rms_norm(h * jax.nn.silu(z.astype(F32)).astype(z.dtype), p["norm_w"], cfg.norm_eps)
    return x + jnp.einsum("bte,ed->btd", h, p["w_down"]), (C, n, m_new)


# ----------------------------------------------------------------------------
# sLSTM block (sequential scan; block-diagonal recurrence per head)
# ----------------------------------------------------------------------------

def init_slstm_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    pf = cfg.xlstm.slstm_proj_factor
    dp = int(pf * d)
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "ln": jnp.ones((d,), dtype),
        "w_gates": (jax.random.normal(ks[0], (d, 4 * d)) * std).astype(dtype),
        "r_gates": (jax.random.normal(ks[1], (H, Dh, 4 * Dh)) * (Dh ** -0.5)).astype(F32),
        "b_gates": jnp.zeros((4 * d,), F32),
        "ln_ffn": jnp.ones((d,), dtype),
        "w_ff1": (jax.random.normal(ks[2], (d, 2 * dp)) * std).astype(dtype),
        "w_ff2": (jax.random.normal(ks[3], (dp, d)) * (dp ** -0.5)).astype(dtype),
    }


def _slstm_cell(carry, gates_x, r, H, Dh):
    """One timestep. carry = (c, n, m, h) each (B, H, Dh); gates_x (B, 4*d)."""
    c, n, m, h = carry
    B = c.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", h, r)                  # (B,H,4*Dh)
    g = gates_x.reshape(B, H, 4 * Dh) + rec
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)               # (B,H,Dh) each
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(lf + m - m_new)
    c_new = fp * c + ip * zt
    n_new = fp * n + ip
    h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_fwd(p, x, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    B, T, _ = x.shape
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    gates_x = (jnp.einsum("btd,de->bte", xn, p["w_gates"]).astype(F32)
               + p["b_gates"])                               # (B,T,4d)

    def step(carry, gx):
        return _slstm_cell(carry, gx, p["r_gates"], H, Dh)

    init = tuple(jnp.zeros((B, H, Dh), F32) for _ in range(2)) + \
        (jnp.full((B, H, Dh), -30.0, F32), jnp.zeros((B, H, Dh), F32))
    _, hs = jax.lax.scan(step, init, gates_x.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, T, d).astype(x.dtype)
    x = x + h
    # GeGLU FFN sub-layer
    xn = L.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", xn, p["w_ff1"])
    a, b = jnp.split(up, 2, axis=-1)
    y = jax.nn.gelu(a.astype(F32)).astype(a.dtype) * b
    return x + jnp.einsum("bte,ed->btd", y, p["w_ff2"])


def slstm_decode(p, x, state, cfg: ModelConfig):
    d = cfg.d_model
    H, Dh = cfg.n_heads, d // cfg.n_heads
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    gx = (jnp.einsum("btd,de->bte", xn, p["w_gates"]).astype(F32) + p["b_gates"])[:, 0]
    state, h = _slstm_cell(state, gx, p["r_gates"], H, Dh)
    x = x + h.reshape(x.shape[0], 1, d).astype(x.dtype)
    xn = L.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    up = jnp.einsum("btd,de->bte", xn, p["w_ff1"])
    a, b = jnp.split(up, 2, axis=-1)
    y = jax.nn.gelu(a.astype(F32)).astype(a.dtype) * b
    return x + jnp.einsum("bte,ed->btd", y, p["w_ff2"]), state


# ----------------------------------------------------------------------------
# Full model: scan over super-blocks of (slstm_every-1) mLSTM + 1 sLSTM
# ----------------------------------------------------------------------------

def _nb(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.xlstm.slstm_every == 0
    return cfg.n_layers // cfg.xlstm.slstm_every


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    nb = _nb(cfg)
    n_m = cfg.xlstm.slstm_every - 1
    ke, km, ks_ = jax.random.split(key, 3)
    mkeys = jax.random.split(km, nb * n_m).reshape(nb, n_m, 2)
    skeys = jax.random.split(ks_, nb)
    return {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype,
                                  cfg.tie_embeddings, cfg.padded_vocab),
        "mlstm": jax.vmap(jax.vmap(lambda k: init_mlstm_block(k, cfg, dtype)))(mkeys),
        "slstm": jax.vmap(lambda k: init_slstm_block(k, cfg, dtype))(skeys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def lm_loss(params, batch, cfg: ModelConfig, *, n_groups: int = 1):
    tokens, targets = batch["tokens"], batch["targets"]
    x = L.embed(params["embed"], tokens)

    def super_block(carry, ps):
        mp_sb, sp = ps

        def inner(c, mp):
            return mlstm_fwd(mp, c, cfg), None
        y, _ = jax.lax.scan(inner, carry, mp_sb)
        return slstm_fwd(sp, y, cfg), None

    super_block = jax.checkpoint(super_block, prevent_cse=False)
    x, _ = jax.lax.scan(super_block, x, (params["mlstm"], params["slstm"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    loss = L.softmax_xent(logits, targets, batch.get("loss_mask"))
    return loss, {"xent": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0,
               window: Optional[int] = None):
    nb = _nb(cfg)
    n_m = cfg.xlstm.slstm_every - 1
    d_in, H, Dh = _mlstm_dims(cfg)
    Hs, Dhs = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros
    return {
        "m_C": z((nb, n_m, batch, H, Dh, Dh), F32),
        "m_n": z((nb, n_m, batch, H, Dh), F32),
        "m_m": jnp.full((nb, n_m, batch, H), -30.0, F32),
        "s_c": z((nb, batch, Hs, Dhs), F32),
        "s_n": z((nb, batch, Hs, Dhs), F32),
        "s_m": jnp.full((nb, batch, Hs, Dhs), -30.0, F32),
        "s_h": z((nb, batch, Hs, Dhs), F32),
    }


def lm_decode_step(params, cache, batch, cfg: ModelConfig, *, n_groups: int = 1,
                   window: Optional[int] = None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)

    def super_block(carry, xs):
        mp_sb, sp, mC, mn, mm, sc, sn, sm, sh = xs
        xc = carry

        def inner(c, mps):
            mp, C, n, m = mps
            y, (C2, n2, m2) = mlstm_decode(mp, c, (C, n, m), cfg)
            return y, (C2, n2, m2)
        xc, (mC2, mn2, mm2) = jax.lax.scan(inner, xc, (mp_sb, mC, mn, mm))
        xc, (sc2, sn2, sm2, sh2) = slstm_decode(sp, xc, (sc, sn, sm, sh), cfg)
        return xc, (mC2, mn2, mm2, sc2, sn2, sm2, sh2)

    xs = (params["mlstm"], params["slstm"], cache["m_C"], cache["m_n"],
          cache["m_m"], cache["s_c"], cache["s_n"], cache["s_m"], cache["s_h"])
    x, (mC, mn, mm, sc, sn, sm, sh) = jax.lax.scan(super_block, x, xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    return logits, {"m_C": mC, "m_n": mn, "m_m": mm, "s_c": sc, "s_n": sn,
                    "s_m": sm, "s_h": sh}


def lm_prefill(params, batch, cfg: ModelConfig, *, n_groups: int = 1,
               window: Optional[int] = None):
    """Prefill = full forward returning last-token logits + final recurrent
    states (built by running the chunked forms and keeping final states)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    # For the recurrent families, prefill logits come from the parallel form;
    # states for continuation are rebuilt by the serving engine. Here we
    # return the states produced by a decode-free pass: run the parallel form
    # for logits and report fresh (empty) states plus a note -- the serving
    # engine replays the tail (see serve/engine.py).
    loss_logits = None
    x = L.embed(params["embed"], tokens)

    def super_block(carry, ps):
        mp_sb, sp = ps

        def inner(c, mp):
            return mlstm_fwd(mp, c, cfg), None
        y, _ = jax.lax.scan(inner, carry, mp_sb)
        return slstm_fwd(sp, y, cfg), None

    x, _ = jax.lax.scan(super_block, x, (params["mlstm"], params["slstm"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:, :], cfg.vocab_size)
    return logits, init_cache(cfg, B)
