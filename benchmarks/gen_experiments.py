"""Regenerate EXPERIMENTS.md from the dry-run artifacts + the §Perf log."""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline_report import load, render_table, summarize

ART = pathlib.Path("benchmarks/artifacts/dryrun")


def perf_cell(tag, arch, shape, mesh="singlepod"):
    f = ART / tag / mesh / f"{arch}__{shape}.json"
    if not f.exists():
        return None
    r = json.loads(f.read_text())
    if r.get("status") != "ok":
        return None
    rr, m = r["roofline"], r["memory"]
    return (f"c={rr['compute_s']:.3g}s m={rr['memory_s']:.3g}s "
            f"x={rr['collective_s']:.3g}s mem={m['peak_per_device_gb']:.1f}GiB "
            f"frac={rr['roofline_fraction']:.3f}")


HEADER = """# EXPERIMENTS

Paper: *Syndeo: Portable Ray Clusters with Secure Containerization* (MIT LL,
2024). All artifacts under `benchmarks/artifacts/`; regenerate this file with
`PYTHONPATH=src:. python benchmarks/gen_experiments.py`.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. Meshes: single pod = `(data=16, model=16)` (256 chips),
multi-pod = `(pod=2, data=16, model=16)` (512 chips).

## §Paper-reproduction (Tables I-IV, Figs 4-5)

The paper's experiment -- RL rollout throughput on a Slurm-hosted cluster,
14 envs x 5 CPU scales (28..868) -- is reproduced by running the REAL Syndeo
scheduler + Global Object Store under the discrete-event backend
(`core/simulator.py`), with a cost model calibrated ONLY from the paper:

* per-interaction compute = 28 / throughput(28 CPUs) (Table III),
* artifact size = 1000 steps x obs_dim x 8 B,
* two free constants fit on two endpoints (Pendulum@868 -> 3.1 ms/task head
  dispatch; Humanoid@868 -> 40 MB/s effective head ingest), held fixed for
  all 70 configurations.

Result (`python -m benchmarks.run`, table written to
`benchmarks/artifacts/paper_tables.txt`): mean |speedup error| vs Table I =
**~1.5x over 70 cells**, and the paper's two headline claims reproduce:
near-linear scaling for cheap envs (Pendulum 20.5x vs paper 20x @868) and the
communication-cost collapse of Humanoid/HumanoidStandup (3.7x/4.1x vs paper
3x/3x) -- emerging from the head's serialized dispatch + 3 MB observation
artifacts, exactly the paper's explanation. The same scheduler code passes
the threaded-backend tests (tests/test_system.py) and the real-TCP protocol
test (tests/test_infra_multi_device.py::test_tcp_worker_protocol).

## §Dry-run (multi-pod proof)

`PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes`

Every (architecture x shape) cell lowers + compiles the real train_step /
prefill / decode_step on BOTH production meshes with ShapeDtypeStruct
stand-ins; `memory_analysis()` / `cost_analysis()` and the SPMD-partitioned
HLO are recorded per cell. The multi-pod pass proves the `pod` axis shards
(DP over `("pod","data")`, EP all-to-alls stay in-pod, FSDP over both DP
axes).

"""

PERF = """
## §Perf -- hillclimb log (hypothesis -> change -> measure -> validate)

Cells chosen per spec: worst roofline cell (arctic-480b x train_4k:
over-memory + biggest model), most representative dense training
(llama3-8b x train_4k), and the serving shape Syndeo fleets run at scale
(qwen1.5-32b x decode_32k). Baseline = paper-faithful implementation
(tag `baseline`, flags `flash_vjp=False, direct_cache=False`); optimized
variants are cumulative and live under their own tags. Stopping rule: three
consecutive <5% changes on the dominant term, or term moved below the next
one.

### llama3-8b x train_4k (single pod)

| it | change | hypothesis | result | verdict |
|---|---|---|---|---|
| 0 | baseline | -- | {llama_base} | memory-dominant |
| 1 | blockwise custom-VJP flash backward (`models/flash_vjp.py`) | differentiating the online-softmax scan stacks per-iteration residuals (p, acc, m, l) to HBM; flash backward recomputes per tile, saving only (q,k,v,o,lse) -> expect memory term down 2-4x | {llama_it1} | **confirmed direction, smaller win than predicted** (-15% memory; attention residuals were ~2 of 13 s -- the rest is weight/activation streaming). Dominant term flipped to collective. |
| 2 | Megatron-style sequence-parallel residual (bind logical "seq" -> model axis) | per-block TP all-reduces become RS+AG pairs -> expect collective wire down ~2x | {llama_it2} | **REFUTED**: collective 12.2->23.1 s. GSPMD did not fuse the pattern; it inserted extra all-gathers around every attention/mlp entry in fwd AND bwd. Reverted (the "seq" binding stays available but off). |
| -- | modeled: Pallas flash (kernels/flash_attention.py) on real TPU | acc/m/l live in VMEM; attention boundary traffic goes to q+k+v+o exactly once | memory term modeled ~6.9s (bytes drop by the measured 3.3e12 attention-fusion boundary bytes/dev) | kernel validated vs oracle in interpret mode; number is modeled, not measured |

Net accepted (XLA-level): memory 12.8 -> 10.9 s (-15%), collective unchanged,
fits 9.5 GiB/chip. Iterations stopped after two refuted follow-ups (<5% rule).

### arctic-480b x train_4k

| it | change | hypothesis | result | verdict |
|---|---|---|---|---|
| 0 | baseline | -- | {arctic_base} | 35.5 GiB: does NOT fit one pod |
| 1 | flash custom-VJP | as above | {arctic_it1} | confirmed small (-6% memory; MoE dominates, attention is a sliver) |
| 2 | sequence-parallel | as above | {arctic_it2} | **REFUTED** on collectives (67->98 s) but -4.5 GiB memory; reverted |
| 3 | bf16 grad accumulation | fp32 accumulator of 480B sharded /256 is 7.5 GiB/chip; bf16 halves it (adafactor tolerates bf16 grads) | {arctic_it3} | confirmed: -3.5 GiB |
| 4 | + per-layer (chunked) adafactor update + mb=16 | per-leaf fp32 update transients (u, g2) materialize at full stacked size (~8 GiB); lax.map over the layer dim cuts them 35x | {arctic_it4} | confirmed: 34.4 -> 24.7 GiB. Still 1.5x over a single pod's HBM. |
| 5 | shard over 2 pods (the production answer) | 480B training state simply exceeds 256x16 GB with any optimizer; the multi-pod mesh halves per-chip state | {arctic_it5} | {arctic_it5_verdict} |

### qwen1.5-32b x decode_32k

| it | change | hypothesis | result | verdict |
|---|---|---|---|---|
| 0 | baseline (int8 KV + 48-head padding + serve-FSDP, in-place carry cache) | -- | {qwen_base} | memory-dominant (decode physics) |
| 1 | bf16 dequantization of int8 blocks | dequant intermediates halve | no change | **REFUTED -- usefully**: the dequant already fuses into the attention dot (boundary-bytes model unchanged); it would not touch HBM on TPU either |
| 2 | block_k 1024 -> 2048 | fewer loop-boundary buffers | no change | refuted (slice totals identical) |
| 3 | direct-indexed 5D-cache attention (no per-layer take/put copies) | cache read drops ~3x -> 1x | {qwen_it3} | **REFUTED at the XLA level**: traced-index scatter breaks while-carry aliasing; the cache is copied per layer (memory 0.32 -> 2.29 s). Reverted; kept selectable for the record. |
| -- | modeled: Pallas decode kernel (kernels/decode_attention.py) | cache streamed exactly once from HBM, dequant in VMEM | floor = (13.3 GB int8 cache + 0.4 GB scales + 0.25 GB weights)/819 GB/s = **17 ms** vs 323 ms parsed XLA-path | kernel validated (incl. int8 path) vs oracle; modeled |

Net: the honest XLA-path number is the baseline 0.323 s; the implemented and
oracle-validated Pallas decode kernel reaches the 17 ms bandwidth floor by
construction (reads counted per BlockSpec tile). Perf score for this cell is
bandwidth-fraction: floor/parsed = 5.2% (XLA ref path) vs ~100% (kernel).

### Methodology notes

* Three refuted hypotheses (SP, bf16-dequant, direct-cache) are recorded
  above with their measured regressions -- each taught us where the cost
  model actually concentrates (GSPMD repartitioning, fusion boundaries,
  aliasing).
* The roofline numbers come from the scan-corrected HLO parser
  (`repro/roofline.py`); a parser improvement mid-campaign (in-place DUS
  operand accounting) re-baselined the decode cells -- baseline and
  iteration numbers above all use the fixed parser.
* All training-cell changes keep the loss math exact (flash-VJP gradients
  validated to 5e-6 vs autodiff; bf16-accum is the only numerics trade and
  is standard for Adafactor-class optimizers).
"""


def main():
    s = summarize()
    lines = [HEADER]
    lines.append(f"Cells: single-pod {s['singlepod']['ok']} ok + "
                 f"{s['singlepod']['skipped']} documented skips "
                 f"(long_500k on full-attention archs), "
                 f"{s['singlepod']['errors']} errors; multi-pod "
                 f"{s['multipod']['ok']} ok + {s['multipod']['skipped']} skips, "
                 f"{s['multipod']['errors']} errors. "
                 f"Fits 16 GiB/chip: {s['singlepod']['fits']}/"
                 f"{s['singlepod']['ok']} single-pod cells "
                 f"(over-budget cells addressed in §Perf; arctic-480b needs "
                 f"2 pods -- see it5).\n")
    lines.append("""## §Roofline (single-pod baselines, all 40 cells)

Conventions: terms are PER-DEVICE seconds from the SPMD-partitioned HLO of
the paper-faithful baseline. FLOPs = 2*prod(out)*contraction per dot,
while-loop bodies multiplied by `known_trip_count`. HBM bytes = fusion
boundary traffic (fused intermediates free; dynamic slices at slice size;
in-place DUS at update size). Collective wire bytes use ring factors
(all-reduce 2(n-1)/n, all-gather n-1, reduce-scatter/all-to-all (n-1)/n,
permute 1) over per-device operand bytes / 50 GB/s/link. `frac` =
compute_term / max(term) (1.0 = compute-bound at roofline); `MODEL/HLO` =
analytic 6*N_active*D / compiled global FLOPs (remat target ~0.75;
whisper's 0.44 reflects the fixed-1536-frame encoder vs the analytic
T^2 cross-attention assumption).
""")
    lines.append(render_table("baseline", "singlepod"))
    lines.append("""
Dominant-bottleneck summary: every train/prefill cell is **memory-term
dominated** on the XLA reference path -- the single biggest contributor is
attention inner-loop boundary traffic, which is precisely what the Pallas
kernels remove (see §Perf); collective terms sit within ~1.1x of memory for
the TP-heavy dense trains (activation all-reduces at TP=16); decode cells
are memory-bound by KV-cache streaming (correct decode physics); the two
long_500k cells (zamba2, xlstm) are tiny in absolute terms -- single-stream
decode does not fill 256 chips, the fleet answer is many concurrent streams
per pod (Syndeo placement groups).

What would move each dominant term down (one line each):
* dense/MoE train_4k: Pallas flash attention (memory) then TP=8 + wider DP
  (collective).
* prefill_32k: same flash kernel; collectives already overlap with compute.
* decode_32k: Pallas decode kernel -> int8-cache streaming floor (~100%
  bandwidth fraction).
* long_500k: batch many streams per replica (the cells are latency-, not
  throughput-relevant at B=1).
* arctic-480b anything: it is a 2-pod model (it5).

### multi-pod (512-chip) table

""")
    lines.append(render_table("baseline", "multipod"))
    lines.append("""
(The single-pod table is the scored one per spec. Multi-pod train/prefill
per-device terms halve as DP doubles -- confirming the pod axis shards
cleanly; decode terms change little because the batch is already spread and
the cache shards over in-pod axes.)
""")

    cells = {
        "llama_base": perf_cell("baseline", "llama3-8b", "train_4k"),
        "llama_it1": perf_cell("it1_flashvjp", "llama3-8b", "train_4k"),
        "llama_it2": perf_cell("it2_sp", "llama3-8b", "train_4k"),
        "arctic_base": perf_cell("baseline", "arctic-480b", "train_4k"),
        "arctic_it1": perf_cell("it1_flashvjp", "arctic-480b", "train_4k"),
        "arctic_it2": perf_cell("it2_sp", "arctic-480b", "train_4k"),
        "arctic_it3": perf_cell("it3_bf16accum", "arctic-480b", "train_4k"),
        "arctic_it4": perf_cell("it4_chunkedopt", "arctic-480b", "train_4k"),
        "arctic_it5": perf_cell("it5_twopod", "arctic-480b", "train_4k",
                                mesh="multipod"),
        "qwen_base": perf_cell("baseline", "qwen1.5-32b", "decode_32k"),
        "qwen_it3": perf_cell("it3_direct", "qwen1.5-32b", "decode_32k"),
    }
    it5 = cells["arctic_it5"]
    cells["arctic_it5_verdict"] = (
        f"**confirmed: {it5}** -- arctic-480b training deploys on 2 pods"
        if it5 and "mem=" in it5 and float(it5.split("mem=")[1].split("GiB")[0]) < 16
        else (f"{it5} -- improved but see note" if it5 else "pending"))
    lines.append(PERF.format(**{k: (v or "n/a") for k, v in cells.items()}))
    pathlib.Path("EXPERIMENTS.md").write_text("\n".join(lines))
    print("EXPERIMENTS.md written",
          len("\n".join(lines).splitlines()), "lines")


if __name__ == "__main__":
    main()
