"""Global invariant checker for the drain/migration chaos harness.

Every chaos scenario -- kill, drain, partition, dropped commit, expired
ticket, at any point of a two-phase move -- must leave the storage layer
in a state where ALL of the following hold (see tests/README.md):

  1. directory ⊆ reality: every location the directory lists actually
     holds the blob (a phantom location would serve as false drain cover
     and could cost the last real copy),
  2. exactly-one owner per live ref: an object with any live copy has
     exactly one owner, and that owner is one of its locations (a move
     must hand ownership off atomically -- never zero owners, never an
     owner pointing at a node that dropped its copy),
  3. in-flight moves are anchored: a PREPAREd move's source still holds
     the object (an aborted/committed move must not linger),
  4. replica coherence: every location of a ref holds byte-identical
     blob content (a broadcast tree relays copies through consumers, so
     a corrupted relay must be caught here, not at first deserialize),
  5. fetchable-set preservation (opt-in): everything fetchable before a
     *graceful* operation is fetchable after it,
  6. zero hot-producer re-execution (opt-in): drains migrate, they never
     recompute.

Call it after the dust settles (it snapshots under the shard locks but
probes node stores outside them, so a racing mutation could
false-positive). The invariants hold per object regardless of the
store's shard count -- `directory_snapshot` collates all shards.
"""
from repro.core import ObjectRef


def check_invariants(store, expect_fetchable=None, scheduler=None,
                     expect_zero_reconstructions=False):
    """Assert the global storage invariants; returns the directory
    snapshot ({oid: (locations, owner, refcount)}) for extra checks."""
    snapshot, nodes, moves = store.directory_snapshot()

    for oid, (locs, owner, _rc) in snapshot.items():
        ref = ObjectRef(oid)
        for n in locs:
            node = nodes.get(n)
            assert node is not None, \
                f"{oid}: directory lists unregistered node {n}"
            assert node.has(ref), \
                f"{oid}: directory lists {n} but its store lacks the blob"
        if locs:
            assert owner is not None and owner in locs, \
                f"{oid}: owner {owner!r} is not among locations {locs}"
        # replica coherence: every copy a broadcast/migration landed is
        # byte-identical (spilled copies included -- export_blob restores
        # through the delta-chunk manifest). Stores that cannot export
        # (e.g. a remote proxy without the blob plane) are skipped.
        blobs = []
        for n in locs:
            try:
                blobs.append((n, nodes[n].export_blob(ref)))
            except (KeyError, OSError, AttributeError):
                continue
        if len(blobs) > 1:
            n0, b0 = blobs[0]
            for n, b in blobs[1:]:
                assert b == b0, \
                    f"{oid}: replica on {n} diverges from copy on {n0}"

    for oid, (src, _dst) in moves.items():
        assert oid in snapshot, f"in-flight move for released object {oid}"
        locs, _, _ = snapshot[oid]
        assert src in locs, \
            f"in-flight move of {oid}: source {src} no longer holds it"

    if expect_fetchable is not None:
        fetchable = {oid for oid, (locs, _, _) in snapshot.items() if locs}
        missing = set(expect_fetchable) - fetchable
        assert not missing, f"fetchable set not preserved: lost {missing}"

    if expect_zero_reconstructions:
        assert store.stats["reconstructions"] == 0, \
            "a graceful operation cost lineage reconstructions"
        if scheduler is not None:
            assert scheduler.stats["reconstructed"] == 0, \
                "a hot producer was re-executed"
    return snapshot
