"""CLI for syndeo-lint: ``python -m repro.analysis [paths...]``.

Exit status 0 when every finding is suppressed by the reviewed
baseline (or there are none); 1 otherwise.  The default baseline is
``analysis/baseline.toml`` relative to the current directory when it
exists -- CI runs from the repo root.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis import run_analysis
from repro.analysis.baseline import apply_baseline, load_baseline

DEFAULT_BASELINE = "analysis/baseline.toml"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Concurrency + wire-protocol lints for the Syndeo "
                    "control plane.")
    ap.add_argument("paths", nargs="*", default=["src/repro/core"],
                    help="files or directories to analyze "
                         "(default: src/repro/core)")
    ap.add_argument("--baseline", default=None,
                    help=f"suppression file (default: "
                         f"{DEFAULT_BASELINE} when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    args = ap.parse_args(argv)

    findings = run_analysis(args.paths)
    entries = []
    if not args.no_baseline:
        baseline = args.baseline
        if baseline is None and Path(DEFAULT_BASELINE).is_file():
            baseline = DEFAULT_BASELINE
        if baseline:
            entries = load_baseline(baseline)
    unsuppressed, suppressed, unused = apply_baseline(findings, entries)

    for f in unsuppressed:
        print(f.render())
    for e in unused:
        print(f"# warning: unused baseline suppression: {e}",
              file=sys.stderr)
    if unsuppressed:
        print(f"# syndeo-lint: {len(unsuppressed)} unsuppressed "
              f"finding(s), {len(suppressed)} suppressed",
              file=sys.stderr)
        return 1
    print(f"# syndeo-lint: clean ({len(suppressed)} finding(s) "
          f"suppressed by baseline)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
