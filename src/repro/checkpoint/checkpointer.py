"""Async sharded checkpointing with elastic (resharding) restore.

Production behaviour on a 1000-node fleet:
  * every host writes only its local shards (here: one process writes
    per-leaf .npy files chunked by the leading axis),
  * a manifest commits atomically via rename -- a crash mid-write never
    corrupts the latest checkpoint,
  * writes happen on a background thread off the training loop (the step
    donates nothing; we snapshot to host numpy first),
  * restore reshards to ANY mesh: arrays are assembled logically and
    re-placed under the target shardings, so a job that lost a pod restarts
    on the survivors (elastic restart),
  * retention: keep_n newest checkpoints are kept, older ones GC'd.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", "?"))))
                       for e in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.stats = {"saves": 0, "restores": 0, "gcs": 0}

    # -- save -------------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = False):
        """Snapshot to host memory synchronously, write asynchronously."""
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        treedef = jax.tree.structure(state)

        def _write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": {}, "written_at": time.time()}
            for key, arr in flat.items():
                fname = key.replace("/", "__") + ".npy"
                logical = str(arr.dtype)
                if logical == "bfloat16":      # numpy can't cast bf16: store bits
                    arr = arr.view(np.uint16)
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": logical}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
            self.stats["saves"] += 1
            self._gc()

        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        return treedef

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ------------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `like`; device_put under `shardings`
        (pytree of NamedSharding) reshards to the current mesh/topology."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        cdir = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        out = {}
        import ml_dtypes
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(cdir, meta["file"]))
            if meta["dtype"] == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if key in flat_like and hasattr(flat_like[key], "dtype"):
                want = flat_like[key].dtype
                if str(arr.dtype) != str(want):
                    arr = np.asarray(jax.numpy.asarray(arr).astype(want))
            sh = flat_sh.get(key)
            out[key] = jax.device_put(arr, sh) if sh is not None else arr
        # reassemble in `like`'s structure
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        keys = ["/".join(str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", "?"))))
                for e in path) for path, _ in leaves_like]
        self.stats["restores"] += 1
        return jax.tree.unflatten(jax.tree.structure(like),
                                  [out[k] for k in keys])

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)
            self.stats["gcs"] += 1
