"""Fixture: multi-blob push frames with wire-protocol defects.

Two blob-plane bugs the batch-frame pass must catch:
* ``drop_many`` declares blobs the handler never iterates -- every
  per-blob declaration the client ships is dead weight on the wire
  (SYN-W001 on the pseudo-op ``drop_many#blob``).
* ``push_many``'s blob loop requires a per-blob ``priority`` field no
  client declaration carries (SYN-W002).
"""


class Server:
    def dispatch(self, msg):
        op = msg.get("op")
        if op == "push_many":
            total = 0
            for b in msg["blobs"]:
                total += b["priority"]
            return {"ok": True, "total": total}
        if op == "drop_many":
            # counts the declarations but never loops over them: the
            # per-blob frames the client assembles have no handler
            return {"ok": True, "count": len(msg.get("blobs") or [])}
        return {"ok": False, "error": f"unknown op {op!r}"}


def push_all(_request, host, port, token, items):
    frame = {"op": "push_many",
             "blobs": [{"object": o, "size": n} for o, n in items]}
    return _request(host, port, token, frame)


def drop_all(_request, host, port, token, items):
    frame = {"op": "drop_many",
             "blobs": [{"object": o, "size": n} for o, n in items]}
    return _request(host, port, token, frame)
