"""Unit + property tests: object store, scheduler, security, simulator,
backend artifact rendering."""
import json
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover -- bare container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (SchedulerConfig, SecurityError, SimCluster,
                        SimCostModel, TaskSpec, TaskState)
from repro.core.backends.base import AllocationRequest
from repro.core.backends.gcp_tpu import GcpTpuBackend
from repro.core.backends.kubernetes import KubernetesBackend
from repro.core.backends.slurm import SlurmBackend
from repro.core.cluster import ContainerSpec
from repro.core.object_store import GlobalObjectStore, NodeStore
from repro.core.security import (Capability, mint_cluster_token, open_sealed,
                                 seal)


# ---------------------------------------------------------------- object store

def test_store_spill_and_restore(tmp_path):
    ns = NodeStore("n0", capacity_bytes=2000, spill_dir=str(tmp_path))
    g = GlobalObjectStore()
    g.register_node(ns)
    refs = [g.put("n0", np.zeros(200, np.uint8)) for _ in range(20)]
    assert ns.stats["spills"] > 0, "LRU spill must trigger over capacity"
    for r in refs:  # everything still readable (restored from disk)
        assert g.get("n0", r).shape == (200,)
    assert ns.stats["restores"] > 0


def test_store_refcount_frees_copies(tmp_path):
    ns = NodeStore("n0", spill_dir=str(tmp_path))
    g = GlobalObjectStore()
    g.register_node(ns)
    ref = g.put("n0", b"payload")
    g.add_ref(ref)          # rc=2
    g.release(ref)          # rc=1 -> still alive
    assert g.get("n0", ref) == b"payload"
    g.release(ref)          # rc=0 -> freed
    assert not g.locations(ref)


def test_store_transfer_tracks_stats():
    g = GlobalObjectStore()
    a, b = NodeStore("a"), NodeStore("b")
    g.register_node(a)
    g.register_node(b)
    ref = g.put("a", np.ones(100))
    _ = g.get("b", ref)          # remote fetch -> transfer
    assert g.stats["transfers"] == 1
    assert "b" in g.locations(ref)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2), min_size=1, max_size=40))
def test_store_refcount_invariant(ops):
    """Property: refcount never resurrects a freed object."""
    g = GlobalObjectStore()
    g.register_node(NodeStore("n"))
    ref = g.put("n", 123)
    rc = 1
    for op in ops:
        if op == 0:
            g.add_ref(ref)
            rc = rc + 1 if rc > 0 else rc
        elif op == 1 and rc > 0:
            g.release(ref)
            rc -= 1
        else:
            alive = bool(g.locations(ref))
            assert alive == (rc > 0)
    assert bool(g.locations(ref)) == (rc > 0)


# ---------------------------------------------------------------- security

def test_hmac_envelope_tamper_rejected():
    tok = mint_cluster_token()
    env = seal(tok, {"op": "join", "worker": "w0"})
    env["body"]["worker"] = "evil"
    with pytest.raises(SecurityError):
        open_sealed(tok, env)


def test_hmac_wrong_token_rejected():
    env = seal(mint_cluster_token(), {"op": "join"})
    with pytest.raises(SecurityError):
        open_sealed(mint_cluster_token(), env)


def test_capability_scoping():
    tok = mint_cluster_token()
    cap = Capability.grant(tok, "obj1", "get")
    cap.check(tok, "obj1", "get")
    with pytest.raises(SecurityError):
        cap.check(tok, "obj1", "put")
    with pytest.raises(SecurityError):
        cap.check(tok, "obj2", "get")


# ---------------------------------------------------------------- simulator / scheduler

def _mk_sim(n_workers=8, **cost_kw):
    cost = SimCostModel(task_time_s=lambda s: 0.1,
                        result_bytes=lambda s: 1000.0, **cost_kw)
    sim = SimCluster(cost, SchedulerConfig(speculation_min_samples=3,
                                           heartbeat_timeout=1e9))
    sim.add_workers(n_workers)
    return sim


def test_sim_runs_wave():
    sim = _mk_sim(8)
    makespan = sim.run_wave([TaskSpec(fn=None, name=f"t{i}") for i in range(32)])
    # 32 tasks / 8 workers ~ 4 sequential rounds of 0.1s
    assert 0.3 < makespan < 1.0


def test_sim_straggler_speculation():
    """A 10x-slow worker's tasks get speculated and the wave still finishes
    near the fast-path time."""
    sim = _mk_sim(8)
    sim.set_worker_speed("w0", 0.05)      # 20x slower
    specs = [TaskSpec(fn=None, group="g") for _ in range(32)]
    makespan = sim.run_wave(specs)
    assert sim.scheduler.stats["speculative"] > 0
    assert makespan < 2.5, f"speculation should cap straggler damage, got {makespan}"


def test_sim_worker_failure_retries():
    sim = _mk_sim(4)
    sim.fail_worker_at("w1", t=0.05)
    specs = [TaskSpec(fn=None) for _ in range(16)]
    makespan = sim.run_wave(specs)
    done = [t for t in sim.scheduler.graph.tasks.values()
            if t.state == TaskState.FINISHED]
    assert len(done) >= 16
    assert sim.scheduler.stats["retried"] >= 0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(6, 20))
def test_sim_always_completes_under_failures(n_fail, n_tasks):
    """Property: any single-failure schedule still completes all tasks."""
    sim = _mk_sim(6)
    for i in range(n_fail):
        sim.fail_worker_at(f"w{i}", t=0.02 * (i + 1))
    sim.run_wave([TaskSpec(fn=None) for _ in range(n_tasks)])
    states = [t.state for t in sim.scheduler.graph.tasks.values()
              if not t.speculative_of]
    assert all(s in (TaskState.FINISHED, TaskState.CANCELLED) for s in states)


def test_scheduler_locality_preference():
    sim = _mk_sim(4)
    sim.run_wave([TaskSpec(fn=None)])
    # place a fat object on w2; a dependent task should choose w2
    ref = sim.store.put("w2", np.zeros(10_000))
    t = sim.submit(TaskSpec(fn=None), deps=[ref])
    sim.run()
    assert sim.scheduler.graph.tasks[t.id].worker == "w2"


# ---------------------------------------------------------------- backends

def _artifacts(backend_cls):
    spec = ContainerSpec(env={"OMP_NUM_THREADS": "1"})
    req = AllocationRequest(nodes=4, cpus_per_node=28,
                            shared_dir="/shared/syndeo")
    return backend_cls(spec).render_artifacts(req, "abc123")


def test_slurm_artifacts_encode_bringup_protocol():
    arts = _artifacts(SlurmBackend)
    sbatch = next(v for k, v in arts.items() if k.endswith(".sbatch"))
    assert "#SBATCH --nodes=4" in sbatch
    assert "apptainer exec" in sbatch
    assert "--writable-tmpfs" in sbatch          # sandbox writes (phase 2)
    assert "head" in sbatch and "worker" in sbatch
    definition = arts["syndeo.def"]
    assert "Bootstrap: docker" in definition


def test_k8s_manifest_is_unprivileged():
    arts = _artifacts(KubernetesBackend)
    y = next(iter(arts.values()))
    assert "runAsNonRoot: true" in y
    assert "replicas: 3" in y                    # nodes-1 workers


def test_gcp_tpu_scripts_nest_schedulers():
    arts = _artifacts(GcpTpuBackend)
    joined = "\n".join(arts.values())
    assert "queued-resources create" in joined   # outer scheduler
    assert "repro.core.worker" in joined         # inner (Syndeo) scheduler
    assert "--privileged=false" in joined
