"""known-bad: open_sealed without a nonce cache (SYN-A003)."""
from repro.core.security import open_sealed


def read_reply(token, envelope):
    return open_sealed(token, envelope)
