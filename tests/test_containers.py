"""Golden-string tests for the container artifact renderers (paper phase 1).

`apptainer_definition` / `apptainer_run_command` feed every backend's
launch artifacts; a silent formatting drift would produce un-runnable
sbatch / K8s scripts, so the full rendered text is pinned here."""
from repro.core.cluster import ContainerSpec
from repro.core.containers import apptainer_definition, apptainer_run_command


def _spec(**kw) -> ContainerSpec:
    defaults = dict(image="syndeo.sif", base="docker://python:3.11-slim",
                    env={"OMP_NUM_THREADS": "1", "JAX_PLATFORMS": "cpu"},
                    binds=["/data:/data", "/scratch:/scratch"],
                    sandbox_writable=True)
    defaults.update(kw)
    return ContainerSpec(**defaults)


GOLDEN_DEFINITION = """\
Bootstrap: docker
From: python:3.11-slim

%files
    src /opt/syndeo/src
    pyproject.toml /opt/syndeo/pyproject.toml

%post
    pip install --no-cache-dir /opt/syndeo
    # containers are immutable after build; runtime writes go to the
    # sandbox tmpfs (--writable-tmpfs) and the bound scratch dir only

%environment
    export PYTHONPATH=/opt/syndeo/src
    export OMP_NUM_THREADS=1
    export JAX_PLATFORMS=cpu

%runscript
    exec python -m repro.core.worker "$@"
"""

GOLDEN_RUN_COMMAND = (
    "apptainer exec --writable-tmpfs "
    "--bind /shared/syndeo:/shared/syndeo "
    "--bind /data:/data --bind /scratch:/scratch "
    "syndeo.sif python -m repro.core.worker "
    "--role worker --rendezvous /shared/syndeo --cluster-id abc123"
)


def test_apptainer_definition_golden():
    assert apptainer_definition(_spec()) == GOLDEN_DEFINITION


def test_apptainer_definition_env_lines_follow_spec_order():
    d = apptainer_definition(_spec(env={"B": "2", "A": "1"}))
    assert "    export B=2\n    export A=1" in d


def test_apptainer_definition_no_env():
    d = apptainer_definition(_spec(env={}))
    # the PYTHONPATH export is structural; no stray blank exports follow
    assert "export PYTHONPATH=/opt/syndeo/src" in d
    assert "export =" not in d


def test_apptainer_run_command_golden():
    cmd = apptainer_run_command(_spec(), role="worker",
                                rendezvous_dir="/shared/syndeo",
                                cluster_id="abc123")
    assert cmd == GOLDEN_RUN_COMMAND


def test_apptainer_run_command_head_role():
    cmd = apptainer_run_command(_spec(), role="head",
                                rendezvous_dir="/rdv", cluster_id="c1")
    assert "--role head" in cmd and "--cluster-id c1" in cmd
    assert "--rendezvous /rdv" in cmd
    # the rendezvous dir is always bound into the container
    assert "--bind /rdv:/rdv" in cmd


def test_apptainer_run_command_writable_tmpfs_toggle():
    ro = apptainer_run_command(_spec(sandbox_writable=False), role="worker",
                               rendezvous_dir="/rdv", cluster_id="c1")
    assert "--writable-tmpfs" not in ro
    rw = apptainer_run_command(_spec(sandbox_writable=True), role="worker",
                               rendezvous_dir="/rdv", cluster_id="c1")
    assert "--writable-tmpfs" in rw


def test_apptainer_run_command_no_extra_binds():
    cmd = apptainer_run_command(_spec(binds=[]), role="worker",
                                rendezvous_dir="/rdv", cluster_id="c1")
    assert cmd.count("--bind") == 1          # just the rendezvous bind
