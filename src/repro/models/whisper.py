"""Whisper-style encoder-decoder backbone (audio family).

Per spec the conv/mel frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, T_enc, d_model). The encoder is a bidirectional
transformer; the decoder has causal self-attention + cross-attention.
T_enc is fixed at 1536 (~30s of frames, padded to the flash block size);
decoder length comes from the assigned shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.axes import constrain

F32 = jnp.float32
ENC_LEN = 1536


def _mlp_init(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    std = d ** -0.5
    return {
        "w1": (jax.random.normal(k1, (d, f)) * std).astype(dtype),
        "w3": (jax.random.normal(k2, (d, f)) * std).astype(dtype),
        "w2": (jax.random.normal(k3, (f, d)) * (f ** -0.5)).astype(dtype),
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    e = cfg.encdec
    ke, kenc, kdec = jax.random.split(key, 3)

    def enc_block(k):
        ka, km = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, False, dtype),
            "mlp": _mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_block(k):
        ka, kx, km = jax.random.split(k, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln_x": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, False, dtype),
            "cross": L.init_attention(kx, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, False, dtype),
            "mlp": _mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
        }

    enc_keys = jax.random.split(kenc, e.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype,
                                  cfg.tie_embeddings, cfg.padded_vocab),
        "enc_layers": jax.vmap(enc_block)(enc_keys),
        "dec_layers": jax.vmap(dec_block)(dec_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def encode(params, enc_embeds, cfg: ModelConfig):
    """enc_embeds: (B, T_enc, d) stub frontend output."""
    B, Te, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32), (B, Te))
    x = constrain(enc_embeds.astype(jnp.dtype(cfg.param_dtype)), "batch", None, None)

    def body(carry, lp):
        h, _ = L.attention(lp["attn"], L.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                           positions, cfg, causal=False)
        xc = carry + h
        y = L.swiglu(L.rms_norm(xc, lp["ln2"], cfg.norm_eps),
                     lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
        return xc + y, None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp, enc_out, cfg):
    B, Te, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("btd,dk->btk", enc_out, lp["cross"]["wk"]).reshape(B, Te, cfg.n_kv_heads, hd)
    v = jnp.einsum("btd,dk->btk", enc_out, lp["cross"]["wv"]).reshape(B, Te, cfg.n_kv_heads, hd)
    return k, v


def _dec_block(lp, x, positions, enc_out, cfg):
    h, kv = L.attention(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                        positions, cfg, causal=True)
    x = x + h
    ck, cv = _cross_kv(lp, enc_out, cfg)
    h, _ = L.attention(lp["cross"], L.rms_norm(x, lp["ln_x"], cfg.norm_eps),
                       positions, cfg, cross_kv=(ck, cv))
    x = x + h
    y = L.swiglu(L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                 lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
    return x + y, kv


def lm_loss(params, batch, cfg: ModelConfig, *, n_groups: int = 1):
    tokens, targets = batch["tokens"], batch["targets"]
    B, T = tokens.shape
    enc_out = encode(params, batch["enc_embeds"], cfg)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = L.embed(params["embed"], tokens)

    def body(carry, lp):
        y, _ = _dec_block(lp, carry, positions, enc_out, cfg)
        return y, None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    loss = L.softmax_xent(logits, targets, batch.get("loss_mask"))
    return loss, {"xent": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    Lc = cfg.n_layers
    return {
        "k": jnp.zeros((Lc, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((Lc, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((Lc, batch, ENC_LEN, cfg.n_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((Lc, batch, ENC_LEN, cfg.n_kv_heads, hd), dtype),
    }


def lm_prefill(params, batch, cfg: ModelConfig, *, n_groups: int = 1,
               window: Optional[int] = None):
    """Encoder pass + decoder prefill; returns (last logits, cache)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    enc_out = encode(params, batch["enc_embeds"], cfg)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = L.embed(params["embed"], tokens)

    def body(carry, lp):
        y, kv = _dec_block(lp, carry, positions, enc_out, cfg)
        ck, cv = _cross_kv(lp, enc_out, cfg)
        return y, (kv[0], kv[1], ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:, :], cfg.vocab_size)
    return logits, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}


def lm_decode_step(params, cache, batch, cfg: ModelConfig, *, n_groups: int = 1,
                   window: Optional[int] = None):
    tokens, pos = batch["tokens"], batch["positions"]
    B = tokens.shape[0]
    hd = cfg.resolved_head_dim
    x = L.embed(params["embed"], tokens)

    def body(carry, xs):
        lp, ck, cv, cxk, cxv = xs
        xc = carry
        xn = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("btd,dq->btq", xn, lp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
        k = jnp.einsum("btd,dk->btk", xn, lp["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
        v = jnp.einsum("btd,dk->btk", xn, lp["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k = L.rope(k, pos[:, None], cfg.rope_theta)
        bidx = jnp.arange(B)
        ck = ck.at[bidx, pos].set(k[:, 0].astype(ck.dtype), mode="drop")
        cv = cv.at[bidx, pos].set(v[:, 0].astype(cv.dtype), mode="drop")
        o = L.flash_attention_ref(q, ck, cv, causal=False, valid_len=pos + 1,
                                  block_q=1, block_k=min(1024, ck.shape[1]))
        xc = xc + jnp.einsum("btq,qd->btd", o.reshape(B, 1, -1), lp["attn"]["wo"])
        # cross attention against cached encoder KV
        xn = L.rms_norm(xc, lp["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("btd,dq->btq", xn, lp["cross"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
        ox = L.flash_attention_ref(qx, cxk, cxv, causal=False, block_q=1,
                                   block_k=min(512, cxk.shape[1]))
        xc = xc + jnp.einsum("btq,qd->btd", ox.reshape(B, 1, -1), lp["cross"]["wo"])
        y = L.swiglu(L.rms_norm(xc, lp["ln2"], cfg.norm_eps),
                     lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
        return xc + y, (ck, cv)

    xs = (params["dec_layers"], cache["k"], cache["v"],
          cache["cross_k"], cache["cross_v"])
    x, (nk, nv) = jax.lax.scan(body, x, xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.vocab_size)
    return logits, {"k": nk, "v": nv, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
