"""Benchmark entrypoint: one function per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV rows.

  table1/2/3+4 : the paper's RL-throughput tables (virtual-time sim on the
                 real Syndeo scheduler; us_per_call = simulated wall per
                 interaction at 868 CPUs; derived = 868-CPU speedup factor)
  bringup      : real threaded cluster bring-up + 64-task wave latency
  kernels      : interpret-mode Pallas kernel micro-checks (us_per_call =
                 host execution; correctness vs oracle is the point on CPU)
  roofline     : summary over the dry-run artifacts (derived = cells ok)
"""
from __future__ import annotations

import sys
import time


def _row(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def bench_paper_tables() -> None:
    from benchmarks.paper_tables import (CPU_CONFIGS, compare_to_paper,
                                         run_all, tables)
    results = run_all(n_seeds=4)
    import numpy as np
    errs = compare_to_paper(results)
    for env, per in sorted(results.items()):
        base = per[28][0]
        big = per[868][0]
        us_per_interaction = 1e6 / big
        _row(f"table1_speedup_{env}", us_per_interaction,
             f"{big / base:.1f}x@868")
    _row("table1_fidelity_mean_abs_speedup_err",
         float(np.mean(list(errs.values()))) * 1e0, "vs_paper_tableI")
    t1, t2, t34 = tables(results)
    with open("benchmarks/artifacts/paper_tables.txt", "w") as f:
        f.write("\n".join(t1) + "\n\n" + "\n".join(t2) + "\n\n" +
                "\n".join(t34) + "\n")


def bench_bringup() -> None:
    from repro.core import SyndeoCluster
    t0 = time.perf_counter()
    with SyndeoCluster() as c:
        for _ in range(4):
            c.add_worker()
        up = time.perf_counter() - t0
        t1 = time.perf_counter()
        tasks = [c.submit(lambda i=i: i * i) for i in range(64)]
        c.wait_all(tasks)
        wave = time.perf_counter() - t1
    _row("cluster_bringup_4workers", up * 1e6, "phases_1_to_3")
    _row("task_wave_64", wave / 64 * 1e6, "per_task_overhead")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    shapes = {
        "flash_attn_b2h4t256d64": lambda: ops.flash_attention(
            jnp.ones((2, 4, 256, 64), jnp.bfloat16),
            jnp.ones((2, 2, 256, 64), jnp.bfloat16),
            jnp.ones((2, 2, 256, 64), jnp.bfloat16), block_q=128, block_k=128),
        "decode_attn_b4h8s512": lambda: ops.decode_attention(
            jnp.ones((4, 8, 64), jnp.bfloat16),
            jnp.ones((4, 2, 512, 64), jnp.bfloat16),
            jnp.ones((4, 2, 512, 64), jnp.bfloat16),
            jnp.full((4,), 512), block_k=256),
        "moe_gmm_e8c64d256f256": lambda: ops.moe_gmm(
            jnp.ones((8, 64, 256), jnp.bfloat16),
            jnp.ones((8, 256, 256), jnp.bfloat16)),
        "ssd_scan_b2h4t256p32": lambda: ops.ssd_scan(
            jnp.ones((2, 4, 256, 32)), jnp.ones((2, 4, 256)) * 0.1,
            -jnp.ones((4,)), jnp.ones((2, 2, 256, 16)) * 0.1,
            jnp.ones((2, 2, 256, 16)) * 0.1, chunk=64),
    }
    for name, fn in shapes.items():
        out = fn()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        dt = (time.perf_counter() - t0) / 3
        _row(f"kernel_{name}", dt * 1e6, "interpret_mode")


def bench_roofline() -> None:
    from benchmarks.roofline_report import load, summarize
    s = summarize()
    for mesh, agg in s.items():
        _row(f"dryrun_{mesh}", 0.0,
             f"ok={agg['ok']};skip={agg['skipped']};err={agg['errors']};"
             f"fits={agg['fits']}/{agg['ok']}")
    for mesh in ("singlepod",):
        for r in load("baseline", mesh):
            if r["status"] != "ok":
                continue
            if (r["arch"], r["shape"]) in (
                    ("llama3-8b", "train_4k"),
                    ("arctic-480b", "train_4k"),
                    ("qwen1.5-32b", "decode_32k")):
                rf = r["roofline"]
                _row(f"roofline_{r['arch']}_{r['shape']}_{mesh}",
                     rf["compute_s"] * 1e6,
                     f"dom={rf['dominant']};frac={rf['roofline_fraction']:.3f}")


def main() -> None:
    import pathlib
    pathlib.Path("benchmarks/artifacts").mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    bench_bringup()
    bench_kernels()
    bench_roofline()
    bench_paper_tables()


if __name__ == "__main__":
    main()
