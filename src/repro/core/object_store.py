"""Global Object Store -- the Syndeo/Ray data plane.

Jobs get their data dependencies from the store and push artifacts back to
it (paper Fig. 1). This implementation provides:

  * ref-counted objects with owner tracking (who holds a copy),
  * LRU spill-to-disk when a node store exceeds its capacity,
  * lineage: every object remembers the task that produced it, so the
    scheduler can *reconstruct* objects lost to node failures by
    re-executing the producing task (Ray-style fault tolerance),
  * capability-scoped access (security.py tokens) -- multi-tenant safety.

Payloads are arbitrary picklable python objects / numpy arrays. On a real
TPU cluster large tensors move as sharded checkpoint files instead; the
store then carries references (paths + manifests), which is exactly how the
paper's shared-filesystem rendezvous behaves.

Control plane vs data plane
---------------------------

The head holds only *metadata*: the directory maps each object id to
``(size, locations, owner, refcount, lineage, tenant)``. Blobs live in the
per-node ``NodeStore``s and move **peer to peer**:

  * ``record(node_id, size, ...)`` registers a result that already lives in
    a (possibly remote) worker's local store -- the metadata-only twin of
    ``put`` with identical tenant/quota admission, but no bytes head-side,
  * ``fetch(node_id, ref, ticket=...)`` materializes a copy on ``node_id``
    by pulling the blob from a peer through the pluggable ``Transport``
    (``InProcessTransport`` for the threaded/sim backends,
    ``TCPTransport`` + a worker-side blob server for real sockets),
  * sources are chosen by locality and link load (``choose_source``:
    prefer peer workers over the head, then the least-trafficked NIC --
    ``link_load`` tracks cumulative bytes per node link),
  * when the head installs the transfer guard (``set_transfer_guard``),
    a worker-destined fetch must present a ``TransferTicket`` whose MAC
    binds (object, source, requesting worker, tenant, expiry) -- minted
    only by the head (``grant_fetch``), so holding the directory answer
    is itself the authorization to move those exact bytes,
  * ``RemoteNodeStore`` is the head-side proxy for a remote worker's
    store: it holds no bytes and serves ``export_blob``/``import_blob``
    over the worker's blob server, which keeps ``get``/``migrate``/
    ``release`` working unchanged over remote nodes.

Wire format (blob server / TCPTransport): every frame is an 8-byte
big-endian length followed by the payload streamed in 64 KiB chunks. A
request is one sealed-JSON frame (HMAC envelope, security.py) naming the
op, object, requester and ticket; a "put"/"get" moves the blob as a second
raw frame whose sha256 is authenticated inside the sealed header.

Drain / migration
-----------------

When the scheduler retires a worker gracefully (DRAINING lifecycle state,
`scheduler.begin_drain`), objects whose *only* copy lives on the retiring
node are **migrated** to a survivor instead of being dropped and later
rebuilt by lineage re-execution:

  * `objects_on(node)` enumerates directory entries held on a node and
    whether the node is the sole holder -- the scheduler's migration
    planner reads this to decide what must move,
  * moves are **two-phase**. `begin_move(ref, src, dst)` (PREPARE)
    records an in-flight move in the directory -- ownership and
    locations stay untouched, so a crash at any point strands nothing.
    The bytes then move *directly* source -> destination (a worker's
    blob server pushes under a head-minted "migrate"-right
    TransferTicket; in-process backends call `complete_move`). Only the
    destination's acknowledgement commits: `commit_move(ref, src, dst)`
    adds the destination location, drops the source one, **hands off
    ownership**, and deletes the source's copy. A move that never acks
    is `abort_move`-ed -- which first *probes* the destination and
    promotes to a commit when the push actually landed and only the ack
    was lost -- and then re-planned by the scheduler. The head's NIC
    carries zero payload bytes for a p2p move; `migrate(ref, src, dst)`
    is the one-call synchronous wrapper (begin + copy + commit) kept for
    in-process node stores and as the relay *fallback* when a direct
    push keeps failing,
  * every phase is capability-checked when the cluster installs a
    migration capability (`set_migration_guard`), so a tenant cannot
    exfiltrate another tenant's objects by draining a shared node,
  * after migration `unregister_node(src)` loses nothing: every hot
    object is served from a survivor, so no lineage reconstruction fires
    (the drain-vs-drop benchmark and the fault-tolerance property tests
    assert exactly this). Unregistering a node also aborts every
    in-flight move that touches it -- a crashed source or destination
    never strands or duplicates ownership.

Cold objects (zero refcount, not depended on) are simply dropped -- the
drain is then provably no worse than recompute: it never re-executes a
producer for a hot object, and never copies garbage.

Multi-tenancy
-------------

Every directory entry carries the *tenant* that put it. Tenant isolation
and accounting are layered on top of the existing machinery:

  * guarded access: once the head installs the cluster token
    (`set_access_guard`), a `get`/`put`/`migrate` that presents a
    Capability has it verified against the object's tenant -- tenant A's
    capability raises SecurityError on tenant B's objects, including when
    a drain tries to migrate them with a tenant-scoped guard,
  * quotas: `set_quota(tenant, TenantQuota(...))` bounds a tenant's live
    directory bytes and entry count. Puts beyond the byte quota either
    reject (`QuotaExceededError`) or spill (the blob lands on disk via the
    node store's spill path instead of memory, so one tenant cannot evict
    everyone else's working set),
  * accounting: `tenant_usage(tenant)` reports live bytes/refs -- the
    fairness benchmark and the autoscaler read this.

The default path (everything under the implicit "default" tenant, no
guard, no quota) is behavior-identical to the single-tenant store.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import socket
import struct
import threading
import time
import uuid
import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core.security import (ADMIN_TENANT, DEFAULT_TENANT, Capability,
                                 NonceCache, SecurityError, TransferTicket,
                                 open_sealed, seal)

#: data-plane framing: 8-byte big-endian length prefix, 64 KiB chunks
FRAME_CHUNK = 64 * 1024
_LEN = struct.Struct(">Q")


def send_frame(sock: socket.socket, payload: bytes):
    """Write one chunked length-prefixed frame."""
    sock.sendall(_LEN.pack(len(payload)))
    view = memoryview(payload)
    for off in range(0, len(view), FRAME_CHUNK):
        sock.sendall(view[off:off + FRAME_CHUNK])


def recv_frame(sock: socket.socket, max_bytes: int = 1 << 32) -> bytes:
    """Read one chunked length-prefixed frame (raises on truncation)."""
    header = _recv_exact(sock, _LEN.size)
    (length,) = _LEN.unpack(header)
    if length > max_bytes:
        raise ValueError(f"frame of {length} bytes exceeds cap {max_bytes}")
    return _recv_exact(sock, length)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    parts = []
    got = 0
    while got < n:
        chunk = sock.recv(min(FRAME_CHUNK, n - got))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        parts.append(chunk)
        got += len(chunk)
    return b"".join(parts)


class QuotaExceededError(SecurityError):
    """A tenant tried to hold more than its admitted share of the store."""


@dataclass(frozen=True)
class TenantQuota:
    """Admission limits for one tenant's footprint in the store.

    `on_exceed="spill"` admits over-quota puts but forces the blob straight
    to the node's spill dir (memory relief at admission time; a later get()
    restores it through the normal LRU, which re-spills under node-capacity
    pressure). On a node without a spill dir the spill policy degrades to
    reject rather than silently keeping the blob in memory."""
    max_bytes: Optional[int] = None     # live directory bytes; None = unlimited
    max_refs: Optional[int] = None      # live directory entries
    on_exceed: str = "reject"           # "reject" | "spill" (bytes only)
    # per-node placement cap consulted by the drain planner: a migration
    # may not land where the tenant already holds this many bytes (keeps
    # one tenant's drain traffic from piling onto the node where it is
    # already memory-rich). Admission (put/record) is not affected.
    max_bytes_per_node: Optional[int] = None


@dataclass(frozen=True)
class ObjectRef:
    id: str
    size: int = 0
    producer_task: Optional[str] = None
    tenant: str = DEFAULT_TENANT

    @staticmethod
    def fresh(producer_task: Optional[str] = None, size: int = 0,
              tenant: str = DEFAULT_TENANT) -> "ObjectRef":
        return ObjectRef(id=uuid.uuid4().hex, size=size,
                         producer_task=producer_task, tenant=tenant)


#: delta-spill chunking: boundaries are content-defined at 1 KiB block
#: granularity (a block whose crc32 matches the mask closes the chunk),
#: bounded to [4 KiB, 64 KiB] so pathological content cannot degenerate
#: into one-chunk or per-byte manifests. Byte-identical regions chunk
#: identically across generations, which is what lets a re-spill skip
#: chunks the prior generation already wrote.
_SPILL_STEP = 1024
_SPILL_MASK = 0x7                       # 1-in-8 blocks: ~12 KiB avg chunk
SPILL_CHUNK_MIN = 4 * 1024
SPILL_CHUNK_MAX = 64 * 1024


def spill_chunk_spans(blob: bytes) -> List[Tuple[int, int]]:
    """Content-defined (start, end) chunk spans covering `blob`."""
    spans: List[Tuple[int, int]] = []
    n = len(blob)
    start = pos = 0
    while pos < n:
        pos = min(n, pos + _SPILL_STEP)
        size = pos - start
        if (pos >= n or size >= SPILL_CHUNK_MAX
                or (size >= SPILL_CHUNK_MIN
                    and (zlib.crc32(blob[pos - _SPILL_STEP:pos])
                         & _SPILL_MASK) == _SPILL_MASK)):
            spans.append((start, pos))
            start = pos
    return spans


class NodeStore:
    """Per-node object store with LRU spill to a scratch directory.

    The spill tier is **delta-encoded**: a spilled blob is stored as a
    manifest (`{spill_dir}/{node}_{oid}.obj`, JSON) naming an ordered
    list of content-chunks that live in `{spill_dir}/{node}_{oid}.chunks/`
    keyed by sha256. Re-spilling a mutated blob writes only the chunks
    the prior generation did not already hold (bytes skipped are counted
    in stats["delta_spill_bytes_saved"]) and prunes chunks the new
    generation dropped. `promote_after` adds disk tiering: a spilled
    blob is promoted back to memory only after that many accesses
    (default 1 = seed semantics, every access restores); colder reads
    are served straight from the chunk store without evicting the
    in-memory working set."""

    def __init__(self, node_id: str, capacity_bytes: int = 1 << 30,
                 spill_dir: Optional[str] = None, promote_after: int = 1):
        self.node_id = node_id
        self.capacity = capacity_bytes
        self.spill_dir = spill_dir
        self.promote_after = max(1, int(promote_after))
        self._mem: "OrderedDict[str, bytes]" = OrderedDict()
        self._spilled: Dict[str, str] = {}           # oid -> manifest path
        self._spill_chunks: Dict[str, List[Tuple[str, int]]] = {}
        self._disk_hits: Dict[str, int] = {}         # accesses since spill
        self._used = 0
        self._lock = threading.Lock()
        self.stats = {"puts": 0, "gets": 0, "spills": 0, "restores": 0,
                      "delta_spill_bytes_saved": 0, "promotions": 0}

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    @property
    def free_bytes(self) -> int:
        with self._lock:
            return max(0, self.capacity - self._used)

    def put(self, ref: ObjectRef, value: Any) -> int:
        return self.put_blob(ref, pickle.dumps(
            value, protocol=pickle.HIGHEST_PROTOCOL))

    def put_blob(self, ref: ObjectRef, blob: bytes) -> int:
        """Store already-serialized bytes (replaces any prior copy)."""
        with self._lock:
            old = self._mem.pop(ref.id, None)
            if old is not None:            # re-put (e.g. reconstruction)
                self._used -= len(old)
            self._mem[ref.id] = blob
            self._mem.move_to_end(ref.id)
            self._used += len(blob)
            self.stats["puts"] += 1
            self._maybe_spill()
        return len(blob)

    def get(self, ref: ObjectRef) -> Any:
        with self._lock:
            self.stats["gets"] += 1
            if ref.id in self._mem:
                self._mem.move_to_end(ref.id)
                return pickle.loads(self._mem[ref.id])
            if ref.id in self._spilled:
                blob = self._read_spill(ref.id)
                hits = self._disk_hits.get(ref.id, 0) + 1
                if hits >= self.promote_after:
                    # hot enough: promote back into the memory tier
                    # (promote_after=1 is the seed's restore-on-access)
                    self._disk_hits.pop(ref.id, None)
                    self.stats["restores"] += 1
                    self.stats["promotions"] += 1
                    self._mem[ref.id] = blob
                    self._used += len(blob)
                    self._maybe_spill()
                else:
                    self._disk_hits[ref.id] = hits
                return pickle.loads(blob)
        raise KeyError(f"object {ref.id} not on node {self.node_id}")

    def has(self, ref: ObjectRef) -> bool:
        with self._lock:
            return ref.id in self._mem or ref.id in self._spilled

    def delete(self, ref: ObjectRef):
        with self._lock:
            blob = self._mem.pop(ref.id, None)
            if blob is not None:
                self._used -= len(blob)
            path = self._spilled.pop(ref.id, None)
            self._spill_chunks.pop(ref.id, None)
            self._disk_hits.pop(ref.id, None)
            if path and os.path.exists(path):
                os.unlink(path)
            cdir = self._chunk_dir(ref.id)
            if cdir and os.path.isdir(cdir):
                for fname in os.listdir(cdir):
                    try:
                        os.unlink(os.path.join(cdir, fname))
                    except OSError:
                        pass
                try:
                    os.rmdir(cdir)
                except OSError:
                    pass

    def export_blob(self, ref: ObjectRef) -> bytes:
        """Raw serialized bytes for migration (no pickle round-trip)."""
        with self._lock:
            if ref.id in self._mem:
                return self._mem[ref.id]
            if ref.id in self._spilled:
                return self._read_spill(ref.id)
        raise KeyError(f"object {ref.id} not on node {self.node_id}")

    def import_blob(self, ref: ObjectRef, blob: bytes) -> bool:
        """Accept migrated bytes verbatim (counterpart of export_blob).
        Returns whether the blob freshly landed -- False when a copy was
        already held, so a retried push never double-counts a receive."""
        with self._lock:
            if ref.id in self._mem or ref.id in self._spilled:
                return False
            self._mem[ref.id] = blob
            self._used += len(blob)
            self.stats["puts"] += 1
            self._maybe_spill()
        return True

    def spill(self, ref: ObjectRef) -> bool:
        """Force one in-memory blob to disk now (tenant-quota spill path).
        Returns False when there is nothing to spill or no spill dir."""
        with self._lock:
            if self.spill_dir is None or ref.id not in self._mem:
                return False
            blob = self._mem.pop(ref.id)
            self._used -= len(blob)
            self._write_spill(ref.id, blob)
            return True

    def _maybe_spill(self):
        """LRU spill until under capacity (lock held)."""
        if self.spill_dir is None:
            return
        while self._used > self.capacity and self._mem:
            oid, blob = self._mem.popitem(last=False)
            self._used -= len(blob)
            self._write_spill(oid, blob)

    def _chunk_dir(self, oid: str) -> Optional[str]:
        if self.spill_dir is None:
            return None
        return os.path.join(self.spill_dir, f"{self.node_id}_{oid}.chunks")

    def _write_spill(self, oid: str, blob: bytes):
        """Spill one generation as a content-chunked delta (lock held):
        chunks already on disk from the prior generation are not
        rewritten, dropped ones are pruned, and the manifest atomically
        names the new generation's ordered chunk list."""
        cdir = self._chunk_dir(oid)
        os.makedirs(cdir, exist_ok=True)
        have = set(os.listdir(cdir))
        manifest: List[Tuple[str, int]] = []
        written = 0
        for start, end in spill_chunk_spans(blob):
            chunk = blob[start:end]
            fname = hashlib.sha256(chunk).hexdigest() + ".chunk"
            manifest.append((fname[:-6], end - start))
            if fname not in have:
                with open(os.path.join(cdir, fname), "wb") as f:
                    f.write(chunk)
                have.add(fname)
                written += end - start
        keep = {h + ".chunk" for h, _ in manifest}
        for fname in have - keep:
            try:
                os.unlink(os.path.join(cdir, fname))
            except OSError:
                pass
        path = os.path.join(self.spill_dir, f"{self.node_id}_{oid}.obj")
        with open(path, "w") as f:
            json.dump({"chunks": [[h, ln] for h, ln in manifest]}, f)
        self._spilled[oid] = path
        self._spill_chunks[oid] = manifest
        self._disk_hits.pop(oid, None)   # a fresh generation re-earns heat
        self.stats["spills"] += 1
        self.stats["delta_spill_bytes_saved"] += len(blob) - written

    def _read_spill(self, oid: str) -> bytes:
        """Reassemble a spilled blob from its chunk store (lock held)."""
        chunks = self._spill_chunks.get(oid)
        if chunks is None:
            with open(self._spilled[oid]) as f:
                chunks = [(h, ln) for h, ln in json.load(f)["chunks"]]
            self._spill_chunks[oid] = chunks
        cdir = self._chunk_dir(oid)
        parts = []
        for h, _ln in chunks:
            with open(os.path.join(cdir, h + ".chunk"), "rb") as f:
                parts.append(f.read())
        return b"".join(parts)


# -- data plane: transports ---------------------------------------------------


class Transport:
    """How blobs move between node stores. The control plane (directory,
    tickets, source choice) stays in GlobalObjectStore; a Transport only
    moves already-authorized bytes."""

    def fetch(self, src_store, ref: ObjectRef,
              ticket: Optional[TransferTicket] = None) -> bytes:
        raise NotImplementedError


class InProcessTransport(Transport):
    """Local/sim data plane: the 'wire' is a function call. Remote-proxy
    node stores still reach their real peers (export_blob does the socket
    work), so this transport is correct for mixed local+remote clusters."""

    def fetch(self, src_store, ref: ObjectRef,
              ticket: Optional[TransferTicket] = None) -> bytes:
        return src_store.export_blob(ref)


class TCPTransport(Transport):
    """Worker-side p2p client: pulls/pushes blobs against a peer's blob
    server (see worker.BlobServer) with the chunked length-prefixed frame
    protocol. `endpoint_of(node_id)` resolves a peer to (host, port)."""

    def __init__(self, endpoint_of: Callable[[str], Optional[Tuple[str, int]]],
                 token: str, requester: str, timeout: float = 15.0):
        self.endpoint_of = endpoint_of
        self.token = token
        self.requester = requester
        self.timeout = timeout
        self._nonces = NonceCache()  # replay guard for peer replies

    def _rpc(self, node_id: str, header: Dict[str, Any],
             blob: Optional[bytes] = None) -> Tuple[Dict[str, Any],
                                                    Optional[bytes]]:
        ep = self.endpoint_of(node_id)
        if ep is None:
            raise KeyError(f"no blob endpoint for node {node_id}")
        with socket.create_connection(tuple(ep), timeout=self.timeout) as s:
            send_frame(s, json.dumps(seal(self.token, header)).encode())
            send_err: Optional[OSError] = None
            if blob is not None:
                try:
                    send_frame(s, blob)
                except OSError as e:
                    # the server may refuse the header and hang up while
                    # we are still streaming the blob; its refusal reply
                    # is often already queued -- prefer reading it so the
                    # caller sees the protocol error (SecurityError, not
                    # a retryable reset that triggers relay fallback)
                    send_err = e
            try:
                reply = open_sealed(self.token,
                                    json.loads(recv_frame(s).decode()),
                                    nonce_cache=self._nonces)
            except (OSError, ValueError):
                if send_err is not None:
                    raise send_err     # genuine transport failure
                raise
            body = None
            if reply.get("ok") and reply.get("size") is not None:
                body = recv_frame(s)
                if len(body) != reply["size"] or hashlib.sha256(
                        body).hexdigest() != reply.get("sha256"):
                    raise SecurityError(
                        f"blob integrity check failed for {header.get('object')}")
        if not reply.get("ok"):
            err = reply.get("error", "blob request refused")
            # the server formats errors as "<TypeName>: <message>" --
            # classify on the exact type-name prefix, never by substring
            # (an object id containing "ticket" must not look like a
            # security failure to recovery paths keyed on KeyError)
            if err.split(":", 1)[0].strip() in ("SecurityError",
                                                "QuotaExceededError"):
                raise SecurityError(err)
            raise KeyError(err)
        return reply, body

    def fetch(self, src_store, ref: ObjectRef,
              ticket: Optional[TransferTicket] = None) -> bytes:
        node_id = src_store if isinstance(src_store, str) else src_store.node_id
        header = {"op": "get", "object": ref.id, "requester": self.requester,
                  "ticket": ticket.to_wire() if ticket else None}
        _, body = self._rpc(node_id, header)
        return body or b""

    def push(self, node_id: str, ref: ObjectRef, blob: bytes,
             ticket: Optional[TransferTicket] = None):
        header = {"op": "put", "object": ref.id, "requester": self.requester,
                  "ticket": ticket.to_wire() if ticket else None,
                  "size": len(blob),
                  "sha256": hashlib.sha256(blob).hexdigest()}
        self._rpc(node_id, header, blob=blob)

    def push_batch(self, node_id: str,
                   items: List[Tuple[ObjectRef, bytes,
                                     Optional[TransferTicket]]]
                   ) -> List[Dict[str, Any]]:
        """Push many blobs to one peer over ONE connection: a single
        sealed header frame declaring every blob (id, size, sha256,
        ticket) followed by ONE multi-blob raw frame -- the blobs
        concatenated in header order. The server verifies every ticket
        before the payload frame is read and replies with per-blob
        verdicts aligned 1:1 with the declarations, so one refused blob
        never poisons the rest. This is what lets a drain plan's many
        small moves amortize the connect/ticket/ack cost of the per-move
        path (see worker.BlobServer `put_batch`)."""
        blobs = [{"object": ref.id, "size": len(blob),
                  "sha256": hashlib.sha256(blob).hexdigest(),
                  "ticket": ticket.to_wire() if ticket else None}
                 for ref, blob, ticket in items]
        header = {"op": "put_batch", "requester": self.requester,
                  "blobs": blobs}
        payload = b"".join(blob for _, blob, _ in items)
        reply, _ = self._rpc(node_id, header, blob=payload)
        results = reply.get("results")
        if not isinstance(results, list) or len(results) != len(items):
            raise KeyError("put_batch reply verdicts misaligned")
        return results

    def has(self, node_id: str, object_id: str,
            ticket: Optional[TransferTicket] = None) -> bool:
        """Existence probe -- ticketed like a fetch: knowing *where* an
        object lives is placement metadata a tenant must not free-ride."""
        try:
            reply, _ = self._rpc(node_id, {
                "op": "has", "object": object_id,
                "requester": self.requester,
                "ticket": ticket.to_wire() if ticket else None})
        except (OSError, KeyError, SecurityError):
            return False
        return bool(reply.get("has"))

    def delete(self, node_id: str, object_id: str,
               ticket: Optional[TransferTicket] = None) -> bool:
        try:
            self._rpc(node_id, {"op": "del", "object": object_id,
                                "requester": self.requester,
                                "ticket": ticket.to_wire() if ticket else None})
        except (OSError, KeyError, SecurityError):
            return False
        return True


class RemoteNodeStore:
    """Head-side *proxy* for a worker's node store in the p2p data plane.

    Holds zero bytes. The directory keeps treating the worker as a regular
    location; export/import/get/delete are served over the worker's blob
    server, authorized by admin transfer tickets minted under the cluster
    token (only the head constructs these proxies). This is what keeps
    `GlobalObjectStore.get/migrate/release` working unchanged when the
    primary copies live outside the head process."""

    #: proxies have no local memory budget -- capacity is the remote
    #: worker's concern (node_free_bytes reports None = unknown)
    capacity = None

    def __init__(self, node_id: str, endpoint: Tuple[str, int], token: str,
                 requester: str = "head", ticket_ttl_s: float = 30.0,
                 control_timeout_s: float = 2.0):
        self.node_id = node_id
        self.endpoint = tuple(endpoint)
        self._token = token
        self._requester = requester
        self._ttl = ticket_ttl_s
        self._transport = TCPTransport(lambda _nid: self.endpoint, token,
                                       requester)
        # control-sized ops (existence probes, deletes) get a short
        # timeout of their own: the migration sweep probes destinations
        # while the head holds its cluster lock, and a partitioned peer
        # must cost ~2 s there, not the blob transport's full 15 s
        self._control = TCPTransport(lambda _nid: self.endpoint, token,
                                     requester, timeout=control_timeout_s)
        self.stats = {"puts": 0, "gets": 0, "spills": 0, "restores": 0}

    def _ticket(self, object_id: str, right: str) -> TransferTicket:
        return TransferTicket.grant(self._token, object_id, self.node_id,
                                    self._requester, ADMIN_TENANT, right,
                                    ttl_s=self._ttl)

    @property
    def used_bytes(self) -> int:
        return 0

    def export_blob(self, ref: ObjectRef) -> bytes:
        self.stats["gets"] += 1
        return self._transport.fetch(self.node_id, ref,
                                     self._ticket(ref.id, "get"))

    def import_blob(self, ref: ObjectRef, blob: bytes) -> bool:
        self.stats["puts"] += 1
        self._transport.push(self.node_id, ref, blob,
                             self._ticket(ref.id, "put"))
        # freshness is the remote store's call; the push either landed or
        # deduplicated there -- report "landed" for the caller's purposes
        return True

    def put_blob(self, ref: ObjectRef, blob: bytes) -> int:
        self.import_blob(ref, blob)
        return len(blob)

    def put(self, ref: ObjectRef, value: Any) -> int:
        return self.put_blob(ref, pickle.dumps(
            value, protocol=pickle.HIGHEST_PROTOCOL))

    def get(self, ref: ObjectRef) -> Any:
        return pickle.loads(self.export_blob(ref))

    def has(self, ref: ObjectRef) -> bool:
        return self._control.has(self.node_id, ref.id,
                                 self._ticket(ref.id, "get"))

    def delete(self, ref: ObjectRef):
        # best-effort distributed GC; an unreachable (dying) worker's
        # copies disappear with the worker anyway
        self._control.delete(self.node_id, ref.id,
                             self._ticket(ref.id, "del"))

    def spill(self, ref: ObjectRef) -> bool:
        return False     # spill policy is the remote worker's own


@dataclass
class _Directory:
    locations: Set[str] = field(default_factory=set)
    refcount: int = 1
    producer_task: Optional[str] = None
    size: int = 0
    created: float = field(default_factory=time.monotonic)
    owner: Optional[str] = None       # node accountable for the primary copy
    tenant: str = DEFAULT_TENANT      # principal accountable for the bytes


@dataclass
class _Move:
    """One PREPAREd (in-flight) migration: src still owns the object and
    still appears in the directory; only commit_move changes either."""
    src: str
    dst: str
    tenant: str = DEFAULT_TENANT
    size: int = 0
    started: float = field(default_factory=time.monotonic)


def shard_key(key: str, shards: int) -> int:
    """Stable shard index for a directory key. crc32, not ``hash()``:
    PYTHONHASHSEED must never move an object between shards across runs
    (tests and operators reason about shard placement by object id)."""
    if shards <= 1:
        return 0
    return zlib.crc32(key.encode("utf-8")) % shards


class _Shard:
    """One partition of the head directory: its own lock plus its slice
    of the object directory, the in-flight moves, and the client-read GC
    hints. Everything keyed by object id lives here; cluster-wide state
    (nodes, quotas, usage, link accounting, stats) stays behind the
    store's meta lock. Lock order is strictly shard -> meta."""

    __slots__ = ("lock", "dir", "moves", "client_reads")

    def __init__(self):
        self.lock = threading.Lock()
        self.dir: Dict[str, _Directory] = {}
        self.moves: Dict[str, _Move] = {}
        self.client_reads: Set[str] = set()


class GlobalObjectStore:
    """Head-side directory over the per-node stores.

    Tracks locations, refcounts and lineage; transfers objects between node
    stores on demand (locality misses are recorded -- the benchmark's
    communication-cost model reads these counters).
    """

    def __init__(self, transport: Optional[Transport] = None,
                 shards: int = 1):
        # the directory is partitioned by shard_key(object_id): every
        # transaction keyed by one object takes only its shard's lock.
        # shards=1 (the default) is the seed-equivalent baseline -- one
        # shard, one lock, identical serialization of every transaction.
        self.shards = max(1, int(shards))
        self._shards = [_Shard() for _ in range(self.shards)]
        self._nodes: Dict[str, NodeStore] = {}
        # meta lock: cluster-wide (non-object-keyed) state -- node table,
        # quotas, usage, link accounting, stats. Lock order shard -> meta.
        self._lock = threading.Lock()
        self._migration_guard = None   # optional (capability, token) pair
        self._token: Optional[str] = None            # set_access_guard
        self._require_tickets = False                # set_transfer_guard
        self._quotas: Dict[str, TenantQuota] = {}
        self._usage: Dict[str, Dict[str, int]] = {}  # tenant -> bytes/refs
        self.transport = transport or InProcessTransport()
        # data-plane load accounting: cumulative bytes over each node's
        # link and per (src, dst) pair -- source choice and the drain
        # planner spread traffic by reading these
        self._link_bytes: Dict[str, int] = {}
        self.bytes_by_link: Dict[Tuple[str, str], int] = {}
        self.stats = {"transfers": 0, "transfer_bytes": 0,
                      "reconstructions": 0,
                      "migrations": 0, "migrated_bytes": 0,
                      "quota_rejects": 0, "quota_spills": 0,
                      "records": 0, "head_relayed_bytes": 0,
                      "ticket_rejects": 0,
                      "moves_started": 0, "moves_committed": 0,
                      "moves_aborted": 0, "relay_fallbacks": 0,
                      "replica_gc": 0,
                      "broadcast_rounds": 0, "tree_edges": 0,
                      "batched_moves": 0}

    def _shard(self, oid: str) -> _Shard:
        return self._shards[shard_key(oid, self.shards)]

    def directory_snapshot(self) -> Tuple[Dict[str, Tuple[Set[str],
                                                          Optional[str], int]],
                                          Dict[str, Any],
                                          Dict[str, Tuple[str, str]]]:
        """Point-in-time view for invariant checkers and tooling:
        ({oid: (locations, owner, refcount)}, {node_id: store},
        {oid: (move_src, move_dst)}). Each shard is snapshotted under its
        own lock; cross-shard atomicity is not part of the directory's
        contract (objects never migrate between shards)."""
        directory: Dict[str, Tuple[Set[str], Optional[str], int]] = {}
        moves: Dict[str, Tuple[str, str]] = {}
        for sh in self._shards:
            with sh.lock:
                for oid, e in sh.dir.items():
                    directory[oid] = (set(e.locations), e.owner, e.refcount)
                for oid, mv in sh.moves.items():
                    moves[oid] = (mv.src, mv.dst)
        with self._lock:
            nodes = dict(self._nodes)
        return directory, nodes, moves

    # -- multi-tenancy: guard, quota, accounting -------------------------------

    def set_access_guard(self, token: str):
        """Install the cluster token so that get/put/migrate calls that
        present a Capability have it verified against the object's tenant.
        Calls without a capability stay trusted (head-internal plumbing);
        the threaded cluster passes per-task tenant capabilities, so every
        worker-side access is verified end to end."""
        self._token = token

    def set_transfer_guard(self, require_tickets: bool = True):
        """Require a valid TransferTicket for every fetch that materializes
        bytes on a *worker* node. The head's own store stays trusted (it is
        the directory authority minting the tickets); everything else must
        present the head's short-lived grant for those exact bytes."""
        self._require_tickets = require_tickets

    # -- data plane: source choice, link accounting, tickets -------------------

    def link_load(self, node_id: str) -> int:
        """Cumulative data-plane bytes over `node_id`'s link (in + out)."""
        with self._lock:
            return self._link_bytes.get(node_id, 0)

    def note_link_bytes(self, src: str, dst: str, size: int):
        """Account one transfer on both endpoints' links. Called internally
        by fetch/migrate and by backends that *model* transfers (the sim's
        virtual NICs) so planners see one coherent load picture."""
        with self._lock:
            self._link_bytes[src] = self._link_bytes.get(src, 0) + size
            self._link_bytes[dst] = self._link_bytes.get(dst, 0) + size
            key = (src, dst)
            self.bytes_by_link[key] = self.bytes_by_link.get(key, 0) + size

    def link_snapshot(self) -> Dict[Tuple[str, str], int]:
        """Copy of the per-(src, dst) byte flows -- the observability
        plane's `syndeo_link_bytes` gauge family reads this, and the
        conformance checker holds the exported gauges against it."""
        with self._lock:
            return dict(self.bytes_by_link)

    def rank_sources(self, ref: ObjectRef, dst: str) -> list:
        """All live serving peers for a fetch onto `dst`, best first:
        prefer worker peers over the head (keep the head's NIC out of the
        data plane), then *fresh* replicas over a copy that is mid-move
        away (the moving source is about to delete its blob under the
        reader), then the least-trafficked link. Candidates are
        pre-sorted by node id and the load comparison is a stable sort,
        so equal-load ties always break in name order regardless of
        set/dict iteration order -- the sharded==single-shard property
        tests rely on this determinism. The single policy behind
        choose_source, the head's ticketed poll replies, and
        broadcast-tree planning."""
        sh = self._shard(ref.id)
        with sh.lock:
            e = sh.dir.get(ref.id)
            locs = set(e.locations) if e else None
            mv = sh.moves.get(ref.id)
            moving_src = mv.src if mv else None
        if locs is None:
            return []
        with self._lock:
            srcs = sorted(n for n in locs if n != dst and n in self._nodes)
            return sorted(srcs, key=lambda n: (n == "head",
                                               n == moving_src,
                                               self._link_bytes.get(n, 0)))

    def choose_source(self, ref: ObjectRef, dst: str) -> Optional[str]:
        """Best serving peer for a fetch onto `dst` (see rank_sources)."""
        ranked = self.rank_sources(ref, dst)
        return ranked[0] if ranked else None

    def replicate_to(self, node_id: str, ref: ObjectRef,
                     acting_tenant: str = ADMIN_TENANT,
                     capability: Optional["Capability"] = None) -> int:
        """Nearest-fresh replication: land a copy of `ref` on `node_id`
        from the best-ranked serving peer (worker peers before the head,
        fresh replicas before mid-move sources, least link load). This is
        how a replica joining an already-broadcast model version gets its
        weights on scale-up -- it pulls from the closest fresh replica
        instead of re-running the broadcast or touching the head link.
        Falls through rank order on per-source failure (a peer dying
        mid-pull); returns bytes moved (0 when already local), raising
        KeyError only when no ranked source could serve."""
        if node_id in self.locations(ref):
            return 0
        last_err: Optional[Exception] = None
        for src in self.rank_sources(ref, node_id):
            ticket = None
            if self._require_tickets and node_id != "head":
                ticket = self.grant_fetch(ref, node_id, acting_tenant,
                                          src=src)
                if ticket is None:
                    continue
            try:
                return self.fetch(node_id, ref, ticket=ticket,
                                  capability=capability, src=src)
            except KeyError as e:       # source lost its copy under us
                last_err = e
        raise last_err or KeyError(
            f"no live source can replicate {ref.id} to {node_id}")

    def grant_fetch(self, ref: ObjectRef, dst: str, acting_tenant: str,
                    ttl_s: float = 30.0,
                    src: Optional[str] = None) -> Optional[TransferTicket]:
        """Head-side ticket mint for one dep fetch: choose a source and
        bind (object, source, destination worker, tenant, expiry) under
        the cluster token. Returns None when `dst` already holds a copy or
        nothing does (caller decides whether that is a miss or a no-op).
        Cross-tenant requests are refused *here*, at mint time -- a task
        acting as tenant B never even learns where tenant A's bytes live."""
        if self._token is None:
            raise SecurityError(
                "cannot mint transfer tickets before set_access_guard")
        tenant = self.tenant_of(ref.id)
        if tenant is None:
            return None
        if acting_tenant != ADMIN_TENANT and acting_tenant != tenant:
            self.stats["ticket_rejects"] += 1
            raise SecurityError(
                f"cross-tenant fetch denied: tenant {acting_tenant!r} "
                f"cannot read an object of tenant {tenant!r}")
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            if e is None or dst in e.locations:
                return None
        src = src if src is not None else self.choose_source(ref, dst)
        if src is None:
            return None
        return TransferTicket.grant(self._token, ref.id, src, dst,
                                    acting_tenant, "get", ttl_s=ttl_s)

    def grant_edge(self, ref: ObjectRef, src: str, dst: str,
                   acting_tenant: str,
                   ttl_s: float = 30.0) -> Optional[TransferTicket]:
        """Mint the ticket for one broadcast-tree edge: authorizes `dst`
        to pull this one object from exactly `src` -- a consumer that
        landed a copy one round ago becomes a legitimate server for the
        next round without ever gaining a wider grant (see
        TransferTicket.grant_edge for the scoping). Same tenant rules as
        grant_fetch; returns None when the edge is moot."""
        if self._token is None:
            raise SecurityError(
                "cannot mint transfer tickets before set_access_guard")
        tenant = self.tenant_of(ref.id)
        if tenant is None:
            return None
        if acting_tenant != ADMIN_TENANT and acting_tenant != tenant:
            self.stats["ticket_rejects"] += 1
            raise SecurityError(
                f"cross-tenant broadcast denied: tenant {acting_tenant!r} "
                f"cannot fan out an object of tenant {tenant!r}")
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            if e is None or dst in e.locations or src not in e.locations:
                return None
        return TransferTicket.grant_edge(self._token, ref.id, src, dst,
                                         acting_tenant, ttl_s=ttl_s)

    def plan_broadcast(self, ref: ObjectRef,
                       consumers: List[str]) -> List[List[Tuple[str, str]]]:
        """Binomial broadcast tree for delivering `ref` to `consumers`:
        a list of rounds, each a list of parallel (src, dst) edges. Every
        consumer that lands a copy in round k serves an edge in round
        k+1, so the holder set doubles per round and N consumers cost
        ~log2(N) rounds of parallel links instead of N serialized pushes
        from the producer's NIC. Deterministic: holders and consumers
        are processed in sorted order, with the head ranked last among
        holders so worker NICs carry the tree whenever they can."""
        held = self.locations(ref)
        with self._lock:
            live = set(self._nodes)
        holders = sorted((n for n in held if n in live),
                         key=lambda n: (n == "head", n))
        pending = [c for c in sorted(set(consumers))
                   if c not in held and c in live]
        rounds: List[List[Tuple[str, str]]] = []
        while pending and holders:
            edges = []
            landed = []
            for src in holders:
                if not pending:
                    break
                dst = pending.pop(0)
                edges.append((src, dst))
                landed.append(dst)
            holders.extend(landed)
            rounds.append(edges)
        return rounds

    def broadcast(self, ref: ObjectRef, consumers: List[str],
                  acting_tenant: str = ADMIN_TENANT,
                  on_round: Optional[Callable[[int], None]] = None) -> int:
        """Deliver `ref` to every consumer through a binomial tree,
        re-planned each round against the live directory: the sources of
        round k+1 are whatever replicas actually landed by the end of
        round k, so a source that dies mid-broadcast (the producer
        included) simply drops out of the next plan and any surviving
        replica serves the rest -- relay, never lineage reconstruction.
        Each edge is authorized by its own per-edge ticket when the
        transfer guard is installed; a refused or failed edge falls back
        to a fresh choose_source fetch in the same round. Returns total
        bytes moved; `on_round(k)` fires after round k (the chaos tests
        kill sources between rounds through it)."""
        delivered = 0
        k = 0
        while True:
            plan = self.plan_broadcast(ref, consumers)
            if not plan or not plan[0]:
                break
            progressed = False
            for src, dst in plan[0]:
                moved = self._broadcast_edge(ref, src, dst, acting_tenant)
                if moved is not None:
                    delivered += moved
                    progressed = True
                with self._lock:
                    self.stats["tree_edges"] += 1
            k += 1
            with self._lock:
                self.stats["broadcast_rounds"] += 1
            if on_round is not None:
                on_round(k)
            if not progressed:
                break      # every edge failed: re-planning cannot help
        return delivered

    def _broadcast_edge(self, ref: ObjectRef, src: str, dst: str,
                        acting_tenant: str) -> Optional[int]:
        """Execute one tree edge; on failure retry via any fresh replica
        (relay-not-lineage). Returns bytes moved, or None when the
        consumer could not be served at all this round."""
        try:
            ticket = None
            if self._require_tickets and dst != "head":
                ticket = self.grant_edge(ref, src, dst, acting_tenant)
                if ticket is None:       # edge went moot (landed/died)
                    return 0 if dst in self.locations(ref) else None
            return self.fetch(dst, ref, ticket=ticket, src=src)
        except (KeyError, SecurityError):
            pass
        try:
            ticket = None
            if self._require_tickets and dst != "head":
                ticket = self.grant_fetch(ref, dst, acting_tenant)
                if ticket is None:
                    return 0 if dst in self.locations(ref) else None
            return self.fetch(dst, ref, ticket=ticket)
        except (KeyError, SecurityError):
            return None

    def set_quota(self, tenant: str, quota: TenantQuota):
        with self._lock:
            self._quotas[tenant] = quota

    def tenant_usage(self, tenant: str) -> Dict[str, int]:
        with self._lock:
            u = self._usage.get(tenant, {})
            return {"bytes": u.get("bytes", 0), "refs": u.get("refs", 0)}

    def quota_of(self, tenant: str) -> Optional[TenantQuota]:
        with self._lock:
            return self._quotas.get(tenant)

    def tenant_bytes_on(self, node_id: str, tenant: str) -> int:
        """Live directory bytes `tenant` holds on one node -- the drain
        planner's quota-aware destination signal (TenantQuota
        .max_bytes_per_node): a move must not land where the tenant is
        already memory-rich."""
        total = 0
        for sh in self._shards:
            with sh.lock:
                total += sum(e.size for e in sh.dir.values()
                             if e.tenant == tenant
                             and node_id in e.locations)
        return total

    def tenant_quota_fraction(self, tenant: str) -> float:
        """Live bytes / byte quota (0.0 when unlimited) -- the pressure
        signal the metrics op and the K8s adapter surface per tenant."""
        with self._lock:
            q = self._quotas.get(tenant)
            if q is None or not q.max_bytes:
                return 0.0
            used = self._usage.get(tenant, {}).get("bytes", 0)
            return used / q.max_bytes

    def quota_tenants(self) -> Set[str]:
        """Tenants with a quota or live usage (metrics enumeration)."""
        with self._lock:
            return set(self._quotas) | set(self._usage)

    def spill_tier_stats(self) -> Dict[str, int]:
        """Sum the delta-spill / disk-tier counters over every node
        store registered in this process. Remote proxies don't carry a
        stats dict (their numbers ride the owning worker's metric
        deltas), so they're skipped via getattr."""
        agg = {"delta_spill_bytes_saved": 0, "promotions": 0}
        with self._lock:
            nodes = list(self._nodes.values())
        for node in nodes:
            stats = getattr(node, "stats", None)
            if not isinstance(stats, dict):
                continue
            for k in agg:
                agg[k] += int(stats.get(k, 0))
        return agg

    def tenant_of(self, ref_or_id) -> Optional[str]:
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._shard(oid).lock:
            e = self._shard(oid).dir.get(oid)
            return e.tenant if e else None

    def _check_capability(self, capability: Optional[Capability],
                          object_id: str, right: str, tenant: str):
        if capability is None:
            return
        if self._token is None:
            raise SecurityError(
                "capability presented but no access guard installed "
                "(head must set_access_guard with the cluster token)")
        capability.verify(self._token, object_id, right, tenant)

    def _usage_add(self, tenant: str, d_bytes: int, d_refs: int):
        """Adjust a tenant's live footprint (lock held)."""
        u = self._usage.setdefault(tenant, {"bytes": 0, "refs": 0})
        u["bytes"] += d_bytes
        u["refs"] += d_refs

    def _quota_verdict(self, tenant: str, add_bytes: int,
                       new_entry: bool) -> Optional[str]:
        """None = admitted; "spill" = admit but keep the blob on disk;
        raises QuotaExceededError on reject (lock held)."""
        q = self._quotas.get(tenant)
        if q is None:
            return None
        u = self._usage.get(tenant, {"bytes": 0, "refs": 0})
        if new_entry and q.max_refs is not None \
                and u["refs"] + 1 > q.max_refs:
            self.stats["quota_rejects"] += 1
            raise QuotaExceededError(
                f"tenant {tenant!r} over ref quota "
                f"({u['refs']}/{q.max_refs} live objects)")
        if q.max_bytes is not None and u["bytes"] + add_bytes > q.max_bytes:
            if q.on_exceed == "spill":
                self.stats["quota_spills"] += 1
                return "spill"
            self.stats["quota_rejects"] += 1
            raise QuotaExceededError(
                f"tenant {tenant!r} over byte quota "
                f"({u['bytes']} + {add_bytes} > {q.max_bytes})")
        return None

    def register_node(self, store: NodeStore):
        with self._lock:
            self._nodes[store.node_id] = store

    def unregister_node(self, node_id: str) -> Set[str]:
        """Remove a (failed) node; returns ids of objects that lost their
        last copy (candidates for lineage reconstruction)."""
        lost = set()
        with self._lock:
            self._nodes.pop(node_id, None)
        aborted = 0
        for sh in self._shards:
            with sh.lock:
                # abort every in-flight move touching the node: a crashed
                # source or destination must never strand half a move (a
                # push that DID land before the source died is recovered
                # when the destination's late ack arrives -- see
                # confirm_replica)
                for oid in [o for o, mv in sh.moves.items()
                            if node_id in (mv.src, mv.dst)]:
                    del sh.moves[oid]
                    aborted += 1
                for oid, entry in sh.dir.items():
                    entry.locations.discard(node_id)
                    if entry.owner == node_id:
                        # owner handoff to any surviving holder
                        entry.owner = next(iter(entry.locations), None)
                    if not entry.locations:
                        lost.add(oid)
        if aborted:
            with self._lock:
                self.stats["moves_aborted"] += aborted
        return lost

    def has_node(self, node_id: str) -> bool:
        with self._lock:
            return node_id in self._nodes

    def node_free_bytes(self, node_id: str) -> Optional[int]:
        """Free in-memory capacity of a node's store; None when unknown
        (remote proxies don't report). The drain planner packs moves under
        this ceiling so a migration never evicts the destination's
        working set."""
        store = self._nodes.get(node_id)
        cap = getattr(store, "capacity", None)
        if store is None or cap is None:
            return None
        return max(0, cap - getattr(store, "used_bytes", 0))

    def put(self, node_id: str, value: Any,
            producer_task: Optional[str] = None,
            ref_id: Optional[str] = None,
            tenant: str = DEFAULT_TENANT,
            capability: Optional[Capability] = None,
            size_hint: Optional[int] = None) -> ObjectRef:
        """Store a new object under `tenant`. `ref_id` pins a deterministic
        object id (Ray-style): a reconstructed producer re-puts under the
        *same* id, so tasks waiting on the original ref wake up when it
        reappears. A presented capability is verified (right "put", tenant
        match); new objects are admitted against the tenant's quota --
        beyond it the put rejects (QuotaExceededError) or spills to disk,
        per the quota's `on_exceed` policy. `size_hint` overrides the
        directory-accounted size (the sim backend stores token payloads
        but models fat artifacts -- timing, locality and quotas must see
        the modeled bytes)."""
        ref = (ObjectRef(ref_id, 0, producer_task, tenant) if ref_id
               else ObjectRef.fresh(producer_task, tenant=tenant))
        self._check_capability(capability, ref.id, "put", tenant)
        node = self._nodes[node_id]
        blob = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        size = len(blob) if size_hint is None else int(size_hint)
        spill = self._admit(ref, node_id, size, producer_task, tenant)
        node.put_blob(ref, blob)
        if spill and not node.spill(ref):
            # "spill" admission requires an actual spill dir on the node:
            # without one the blob would silently stay in memory, defeating
            # the quota -- unwind the registration and reject instead
            sh = self._shard(ref.id)
            with sh.lock:
                e2 = sh.dir.get(ref.id)
                with self._lock:
                    if e2 is not None and e2.locations == {node_id}:
                        self._usage_add(e2.tenant, -e2.size, -1)
                        del sh.dir[ref.id]
                    self.stats["quota_spills"] -= 1
                    self.stats["quota_rejects"] += 1
            self._nodes[node_id].delete(ref)
            raise QuotaExceededError(
                f"tenant {tenant!r} over byte quota and node {node_id!r} "
                f"has no spill dir (on_exceed='spill' degraded to reject)")
        return ObjectRef(ref.id, size, producer_task, tenant)

    def _admit(self, ref: ObjectRef, node_id: str, size: int,
               producer_task: Optional[str], tenant: str) -> bool:
        """One atomic directory transaction deciding admission (tenant
        check + quota + registration) *before* any bytes land anywhere:
        concurrent cross-tenant puts of the same id cannot both pass the
        check and overwrite each other's blobs (the loser raises without
        ever writing). Returns True when the quota verdict is "spill"."""
        sh = self._shard(ref.id)
        with sh.lock:
            e = sh.dir.get(ref.id)
            if e is not None and e.tenant != tenant:
                raise SecurityError(
                    f"cross-tenant put denied: object {ref.id} belongs to "
                    f"tenant {e.tenant!r}, not {tenant!r}")
            if e is not None:              # reconstruction: revive the entry
                # already-admitted object: only the size delta is accounted
                # (no re-admission -- rolling back a revival would lose the
                # blob a waiting task is about to read)
                with self._lock:
                    self._usage_add(e.tenant, size - e.size, 0)
                e.locations.add(node_id)
                e.size = size
                e.producer_task = producer_task or e.producer_task
                if e.owner is None:
                    e.owner = node_id
                return False
            with self._lock:
                spill = self._quota_verdict(tenant, size,
                                            new_entry=True) == "spill"
                self._usage_add(tenant, size, 1)
            sh.dir[ref.id] = _Directory(locations={node_id},
                                        producer_task=producer_task,
                                        size=size, owner=node_id,
                                        tenant=tenant)
            return spill

    def record(self, node_id: str, size: int,
               producer_task: Optional[str] = None,
               ref_id: Optional[str] = None,
               tenant: str = DEFAULT_TENANT,
               capability: Optional[Capability] = None
               ) -> Tuple[ObjectRef, bool]:
        """Metadata-only result registration: the blob already lives in
        `node_id`'s local store (a remote worker's data plane); the head
        records only (ref, size, location, owner, tenant). Admission is
        byte-for-byte the same transaction as `put` -- quota rejects raise
        here exactly like a relayed put would -- but no payload ever
        transits the head. Returns (ref, spill): a True spill verdict asks
        the *owner* to move its local copy to disk (the head cannot)."""
        if node_id not in self._nodes:
            raise KeyError(f"cannot record object on unknown node {node_id}")
        ref = (ObjectRef(ref_id, size, producer_task, tenant) if ref_id
               else ObjectRef.fresh(producer_task, size=size, tenant=tenant))
        self._check_capability(capability, ref.id, "put", tenant)
        spill = self._admit(ref, node_id, size, producer_task, tenant)
        self.stats["records"] += 1
        return ObjectRef(ref.id, size, producer_task, tenant), spill

    def get(self, node_id: str, ref: ObjectRef,
            capability: Optional[Capability] = None,
            ticket: Optional[TransferTicket] = None) -> Any:
        """Fetch on `node_id`, transferring from a remote copy if needed.
        A presented capability is verified against the object's tenant;
        with the transfer guard installed, worker-destined transfers also
        need a `ticket` (see fetch)."""
        with self._shard(ref.id).lock:
            entry = self._shard(ref.id).dir.get(ref.id)
            local = node_id in (entry.locations if entry else ())
            tenant = entry.tenant if entry else ref.tenant
        self._check_capability(capability, ref.id, "get", tenant)
        if local or (entry is None):
            return self._nodes[node_id].get(ref)
        self.fetch(node_id, ref, ticket=ticket)
        return self._nodes[node_id].get(ref)

    def fetch(self, node_id: str, ref: ObjectRef,
              ticket: Optional[TransferTicket] = None,
              capability: Optional[Capability] = None,
              src: Optional[str] = None) -> int:
        """Materialize a copy of `ref` on `node_id` through the data plane:
        pick a source (ticket-pinned, else by locality + link load), move
        the raw blob via the Transport, record the new location. Returns
        bytes moved (0 when already local). With the transfer guard
        installed, a worker-destined fetch without a ticket whose MAC binds
        this exact (object, source, destination, tenant) is refused -- the
        head's own store stays trusted, everything else pays the toll."""
        sh = self._shard(ref.id)
        with sh.lock:
            entry = sh.dir.get(ref.id)
            if entry is None:
                raise KeyError(f"object {ref.id} is not in the directory")
            if node_id in entry.locations:
                return 0
            tenant = entry.tenant
        self._check_capability(capability, ref.id, "get", tenant)
        if src is not None and (src not in self.locations(ref)
                                or src not in self._nodes):
            src = None                 # stale pin: fall through to choice
        if self._require_tickets and node_id != "head":
            if ticket is None:
                if self.choose_source(ref, node_id) is None:
                    # no copies is the real condition -- report it as such
                    # (KeyError drives lineage reconstruction, a ticket
                    # complaint would mask it)
                    raise KeyError(f"object {ref.id} has no live copies")
                self.stats["ticket_rejects"] += 1
                raise SecurityError(
                    f"transfer ticket required to fetch {ref.id} "
                    f"onto {node_id}")
            try:
                ticket.verify(self._token or "", ref.id, ticket.src,
                              node_id, "get", tenant)
            except SecurityError:
                self.stats["ticket_rejects"] += 1
                raise
            src = ticket.src
            if src not in self.locations(ref) or src not in self._nodes:
                raise KeyError(
                    f"ticket source {src} no longer holds {ref.id}")
        elif ticket is not None and ticket.src in self.locations(ref) \
                and ticket.src in self._nodes:
            src = ticket.src           # honor the head's placement hint
        if src is None:
            src = self.choose_source(ref, node_id)
        if src is None:
            raise KeyError(f"object {ref.id} has no live copies")
        blob = self.transport.fetch(self._nodes[src], ref, ticket)
        self._nodes[node_id].import_blob(ref, blob)
        released, fresh = False, False
        with sh.lock:
            e = sh.dir.get(ref.id)
            if e is None:              # released mid-fetch
                released = True
            else:
                # the directory size is authoritative (it may be a modeled
                # size_hint larger than the physical token blob)
                size = e.size if e.size else len(blob)
                # attempt-idempotent accounting: a concurrent/retried
                # fetch of the same copy commits the location once, so
                # transfer and link counters never double-charge one blob
                fresh = node_id not in e.locations
                e.locations.add(node_id)
                if fresh:
                    with self._lock:
                        self.stats["transfers"] += 1
                        self.stats["transfer_bytes"] += size
                        if src == "head":
                            # bytes the head's NIC served to the data
                            # plane -- the p2p-vs-relay benchmarks read
                            # exactly this counter
                            self.stats["head_relayed_bytes"] += size
        if released:
            # drop the stale import outside the lock: the node may be a
            # remote proxy, making this a TCP round-trip
            self._nodes[node_id].delete(ref)
            return 0
        if not fresh:
            return 0
        self.note_link_bytes(src, node_id, size)
        return size

    def confirm_replica(self, ref_or_id, node_id: str) -> bool:
        """Verify-then-record a claimed out-of-band replica: the node's
        store is probed for the blob (a ticketed TCP `has` for remote
        proxies) before the directory believes it. An unverified claim
        would count as drain cover and could cost the last real copy."""
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._shard(oid).lock:
            known = oid in self._shard(oid).dir
        with self._lock:
            node = self._nodes.get(node_id)
        if node is None or not known:
            return False
        try:
            if not node.has(ObjectRef(oid)):
                return False
        except Exception:  # noqa: BLE001 -- unreachable node = unconfirmed
            return False
        self.note_replica(oid, node_id)
        return True

    def purge_copy(self, ref_or_id, node_id: str) -> bool:
        """Best-effort delete of a node's copy of an object the directory
        no longer tracks (e.g. a drain push that landed after the object
        was released) -- refuses to touch copies of live objects. A
        control-sized `del` for remote stores."""
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._shard(oid).lock:
            if oid in self._shard(oid).dir:
                return False
        with self._lock:
            node = self._nodes.get(node_id)
        if node is None:
            return False
        try:
            node.delete(ObjectRef(oid))
        except Exception:  # noqa: BLE001 -- unreachable peer: its copy
            return False   # disappears with it anyway
        return True

    def note_replica(self, ref_or_id, node_id: str):
        """Record that a copy of an object landed on `node_id` through an
        out-of-band data-plane move (e.g. a leaving worker's replication
        pushes) -- directory-only, the bytes already moved peer to peer."""
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        sh = self._shard(oid)
        with sh.lock:
            e = sh.dir.get(oid)
            with self._lock:
                node_known = node_id in self._nodes
            if e is not None and node_known:
                e.locations.add(node_id)
                if e.owner is None:
                    e.owner = node_id

    def locations(self, ref: ObjectRef) -> Set[str]:
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            return set(e.locations) if e else set()

    def size_of(self, ref: ObjectRef) -> int:
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            return e.size if e else ref.size

    def lineage(self, ref: ObjectRef) -> Optional[str]:
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            return e.producer_task if e else ref.producer_task

    def add_ref(self, ref: ObjectRef, n: int = 1):
        with self._shard(ref.id).lock:
            d = self._shard(ref.id).dir
            if ref.id in d:
                d[ref.id].refcount += n

    def mark_client_read(self, ref_or_id):
        """GC hint: the head's copy of this object exists only because a
        client read materialized it (the owner's copy is elsewhere). Such
        replicas are dropped as soon as the refcount next drops -- the
        head store is a staging buffer, not a cache for the cluster
        lifetime (see release)."""
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        sh = self._shard(oid)
        with sh.lock:
            e = sh.dir.get(oid)
            if (e is not None and "head" in e.locations
                    and e.owner != "head" and len(e.locations) > 1):
                sh.client_reads.add(oid)

    def release(self, ref: ObjectRef):
        """Decrement refcount; free all copies at zero. A refcount drop
        that leaves the object alive still GCs hinted client-read head
        replicas (mark_client_read) -- the owner keeps serving."""
        gc_head = None
        freed = False
        mv, locs = None, set()
        sh = self._shard(ref.id)
        with sh.lock:
            e = sh.dir.get(ref.id)
            if e is None:
                return
            e.refcount -= 1
            if e.refcount > 0:
                if (ref.id in sh.client_reads and "head" in e.locations
                        and e.owner != "head" and len(e.locations) > 1):
                    e.locations.discard("head")
                    sh.client_reads.discard(ref.id)
                    with self._lock:
                        self.stats["replica_gc"] += 1
                        gc_head = self._nodes.get("head")
            else:
                freed = True
                locs = set(e.locations)
                mv = sh.moves.pop(ref.id, None)
                sh.client_reads.discard(ref.id)
                with self._lock:
                    self._usage_add(e.tenant, -e.size, -1)
                del sh.dir[ref.id]
        if gc_head is not None:
            gc_head.delete(ref)
        if not freed:     # decided under the lock: a racing final release
            return        # must not send this thread down the free path
        if mv is not None and mv.dst not in locs:
            # a push was in flight: the destination may hold an
            # unregistered partial copy -- best-effort drop it too
            dst_store = self._nodes.get(mv.dst)
            if dst_store is not None:
                try:
                    dst_store.delete(ref)
                except Exception:  # noqa: BLE001 -- unreachable peer
                    pass
        for node_id in locs:
            store = self._nodes.get(node_id)
            if store is not None:
                store.delete(ref)

    def note_reconstruction(self):
        with self._lock:
            self.stats["reconstructions"] += 1

    # -- drain / migration (see module docstring) -----------------------------

    def set_migration_guard(self, capability, token: str):
        """Require `capability` (right "migrate") for every migrate() call.
        Installed by the cluster head with a capability minted under the
        cluster token -- a tenant without it cannot move objects around."""
        self._migration_guard = (capability, token)

    def owner_of(self, ref: ObjectRef) -> Optional[str]:
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            return e.owner if e else None

    def refcount(self, ref_or_id) -> int:
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._shard(oid).lock:
            e = self._shard(oid).dir.get(oid)
            return e.refcount if e else 0

    def objects_on(self, node_id: str) -> Dict[str, "ObjectRef"]:
        """Directory entries with a copy on `node_id`, keyed by object id.
        The migration planner filters these for sole-holder hot objects."""
        out: Dict[str, ObjectRef] = {}
        for sh in self._shards:
            with sh.lock:
                for oid, e in sh.dir.items():
                    if node_id in e.locations:
                        out[oid] = ObjectRef(oid, e.size, e.producer_task,
                                             e.tenant)
        return out

    def sole_holder(self, ref: ObjectRef, node_id: str) -> bool:
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            return bool(e) and e.locations == {node_id}

    def _check_migration_guard(self, ref: ObjectRef,
                               capability: Optional[Capability]):
        """Tenant-aware migration guard shared by every phase of a move:
        the presented capability (or the installed migration guard's)
        must cover the object's tenant. The head's guard is cluster-scoped
        (admin) and moves anything; a tenant-scoped capability raises
        SecurityError on another tenant's objects -- also when a drain
        tries to use it."""
        cap, token = capability, self._token
        if self._migration_guard is not None:
            guard_cap, guard_token = self._migration_guard
            cap = cap if cap is not None else guard_cap
            token = token if token is not None else guard_token
        if cap is not None:
            if token is None:
                raise SecurityError(
                    "capability presented but no access guard installed")
            cap.verify(token, "objects", "migrate",
                       self.tenant_of(ref.id) or ref.tenant)

    # -- two-phase move protocol (PREPARE / push / COMMIT / ABORT) ------------

    def begin_move(self, ref: ObjectRef, src: str, dst: str,
                   capability: Optional[Capability] = None) -> bool:
        """PREPARE one migration: guard-check it and record the in-flight
        move. The directory is untouched -- src still owns the object and
        serves reads -- so a crash anywhere before COMMIT strands nothing.
        Returns False when the move is moot (object gone, src copy gone,
        dst unregistered) or the object is already mid-move."""
        self._check_migration_guard(ref, capability)
        sh = self._shard(ref.id)
        with sh.lock:
            e = sh.dir.get(ref.id)
            with self._lock:
                dst_known = dst in self._nodes
            if (e is None or src not in e.locations
                    or not dst_known or ref.id in sh.moves):
                return False
            sh.moves[ref.id] = _Move(src, dst, e.tenant,
                                     e.size if e.size else ref.size)
            with self._lock:
                self.stats["moves_started"] += 1
        return True

    def migrate_ticket(self, ref: ObjectRef, src: str, dst: str,
                       ttl_s: float = 60.0) -> TransferTicket:
        """Mint the push grant for a PREPAREd move: authorizes `src` (and
        only `src`) to push this one object into `dst`'s blob store under
        the "migrate" right. Head-only (requires the cluster token)."""
        if self._token is None:
            raise SecurityError(
                "cannot mint migrate tickets before set_access_guard")
        tenant = self.tenant_of(ref.id) or ref.tenant
        return TransferTicket.grant_migrate(self._token, ref.id, dst, src,
                                            tenant, ttl_s=ttl_s)

    def move_in_flight(self, ref_or_id) -> Optional[Tuple[str, str]]:
        """(src, dst) of the object's in-flight move, or None."""
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        with self._shard(oid).lock:
            mv = self._shard(oid).moves.get(oid)
            return (mv.src, mv.dst) if mv else None

    def commit_move(self, ref_or_id, src: str, dst: str) -> bool:
        """COMMIT a PREPAREd move once the destination confirmed it holds
        the blob (its metadata ack, or an explicit probe): record the new
        location, drop the old one, hand off ownership, and delete the
        source's copy (a control-sized `del` for remote stores -- no
        payload transits the head). Returns False when no matching move
        is in flight or the object was released mid-move (the pushed
        copy is dropped rather than stranded)."""
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        ref = ObjectRef(oid)
        cleanup, failed = None, False
        sh = self._shard(oid)
        with sh.lock:
            mv = sh.moves.get(oid)
            if mv is None or mv.src != src or mv.dst != dst:
                return False
            del sh.moves[oid]
            e = sh.dir.get(oid)
            with self._lock:
                dst_store = self._nodes.get(dst)
                src_store = self._nodes.get(src)
            if e is None or dst_store is None:
                cleanup, failed = dst_store, True
            else:
                # the directory size is authoritative (size_hint-modeled
                # blobs carry token payloads): the planner's link_load
                # signal must see the modeled bytes, same as fetch()
                size = e.size if e.size else mv.size
                e.locations.add(dst)
                e.locations.discard(src)
                if e.owner == src or e.owner is None:
                    e.owner = dst            # owner handoff
                with self._lock:
                    self.stats["migrations"] += 1
                    self.stats["migrated_bytes"] += size
                    self.stats["moves_committed"] += 1
        if failed:         # released, or destination unregistered, mid-move
            if cleanup is not None:
                try:
                    cleanup.delete(ref)
                except Exception:  # noqa: BLE001 -- best-effort GC
                    pass
            return False
        self.note_link_bytes(src, dst, size)
        if src_store is not None:
            try:
                src_store.delete(ref)
            except Exception:  # noqa: BLE001 -- a dying source's copy
                pass           # disappears with the source anyway
        return True

    def abort_move(self, ref_or_id, probe: bool = True) -> bool:
        """ABORT a move that never acked. With `probe` (the default when
        the destination might be alive), the destination store is asked
        whether the push actually landed -- if it did, the move is
        *promoted to a COMMIT* instead (the ack, not the push, was lost)
        and True is returned. Otherwise the in-flight record is dropped,
        the directory is untouched (src still owns the object), and the
        caller re-plans. Returns whether the move ended up committed."""
        oid = ref_or_id.id if isinstance(ref_or_id, ObjectRef) else ref_or_id
        sh = self._shard(oid)
        with sh.lock:
            mv = sh.moves.get(oid)
            if mv is None:
                return False
        with self._lock:
            dst_store = self._nodes.get(mv.dst) if probe else None
        if dst_store is not None:
            held = False
            try:
                held = dst_store.has(ObjectRef(oid))
            except Exception:  # noqa: BLE001 -- unreachable = not landed
                held = False
            if held and self.commit_move(oid, mv.src, mv.dst):
                return True
        with sh.lock:
            if sh.moves.pop(oid, None) is None:
                return False               # raced a commit/release
            with self._lock:
                self.stats["moves_aborted"] += 1
        return False

    def complete_move(self, ref: ObjectRef, src: str, dst: str) -> bool:
        """Execute the data copy for a PREPAREd move and COMMIT it -- the
        in-process path (threaded/sim backends and the head-relay
        fallback, where this process can reach both stores). The TCP p2p
        path never calls this: the source worker pushes and the
        destination's ack commits."""
        with self._shard(ref.id).lock:
            mv = self._shard(ref.id).moves.get(ref.id)
        with self._lock:
            src_store = self._nodes.get(src)
            dst_store = self._nodes.get(dst)
        if mv is None or mv.src != src or mv.dst != dst:
            return False
        if src_store is None or dst_store is None:
            return self.abort_move(ref.id, probe=False)
        try:
            blob = src_store.export_blob(ref)
            dst_store.import_blob(ref, blob)
        except Exception:  # noqa: BLE001 -- src blob/peer gone mid-copy
            return self.abort_move(ref.id, probe=True)
        if self.commit_move(ref.id, src, dst):
            return True
        # commit refused (released or aborted mid-copy): drop the copy we
        # just imported unless the directory adopted it meanwhile
        with self._shard(ref.id).lock:
            e = self._shard(ref.id).dir.get(ref.id)
            adopted = e is not None and dst in e.locations
        if not adopted:
            try:
                dst_store.delete(ref)
            except Exception:  # noqa: BLE001
                pass
        return False

    def migrate(self, ref: ObjectRef, src: str, dst: str,
                capability: Optional[Capability] = None) -> bool:
        """Move one object's copy src -> dst (raw blob, no pickle
        round-trip) through the two-phase protocol in one synchronous
        call: PREPARE, copy, COMMIT. Returns False when the move is moot
        (object gone, src copy gone, or dst unregistered) -- drains treat
        that as already-done. Over RemoteNodeStore proxies this relays
        the blob through the calling process -- which is exactly why the
        p2p drain path replaced it with direct pushes; it remains the
        backward-compat path and the transient-transport fallback."""
        self._check_migration_guard(ref, capability)
        sh = self._shard(ref.id)
        with sh.lock:
            e = sh.dir.get(ref.id)
            with self._lock:
                src_store = self._nodes.get(src)
                dst_store = self._nodes.get(dst)
            if e is None or src not in e.locations or dst_store is None:
                return False
            already_there = dst in e.locations
            if already_there:                # already replicated there
                e.locations.discard(src)
                if e.owner == src:
                    e.owner = dst
        if already_there:
            if src_store is not None:        # drop the now-unreachable blob
                src_store.delete(ref)
            return True
        if src_store is None:
            return False
        if not self.begin_move(ref, src, dst, capability=capability):
            return False
        return self.complete_move(ref, src, dst)
