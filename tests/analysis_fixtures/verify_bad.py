"""known-bad: op branch mutates the store before _verify (SYN-A002)."""


class TicketedServer:
    def __init__(self, store):
        self.store = store

    def _verify(self, header, right):
        raise NotImplementedError

    def dispatch(self, header, blob):
        op = header.get("op")
        if op == "put":
            self.store.import_blob(header["object"], blob)
            self._verify(header, "put")       # too late: already wrote
            return {"ok": True}
        if op == "del":
            self._verify(header, "del")
            self.store.delete(header["object"])
            return {"ok": True}
        return {"ok": False, "error": f"bad op {op}"}
