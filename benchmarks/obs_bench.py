"""Observability overhead benchmark: metric deltas must ride for free.

The whole point of piggybacking worker telemetry on the poll `batch`
frame is that a monitored cluster speaks EXACTLY as many wire frames as
an unmonitored one -- the deltas share the socket round trip and the
one cluster-lock pass the poll already pays for. This benchmark drives
the hot result/poll path through the in-process ``HeadServer.dispatch``
at 100 workers on TWO live clusters at once -- one monitored (counter
deltas + a sparse poll-latency histogram delta ride every
``METRICS_EVERY``-th poll frame, exactly ``run_worker``'s telemetry
cadence), one bare -- and measures:

* frames per poll -- must be IDENTICAL across the arms (the deltas add
  zero wire frames; a regression that gives telemetry its own frame or
  its own connection fails here),
* result throughput -- the head-side fold (dict arithmetic plus an
  element-wise histogram add under the lock it already holds) must cost
  < 5% of the metrics-off results/sec. The arms alternate ROUND BY
  ROUND and the gate is the median of time ratios over cadence-aligned
  BLOCKS of METRICS_EVERY round pairs: adjacent rounds see
  near-identical machine conditions, so ambient CPU noise (which
  dwarfs a few percent on shared runners) cancels instead of deciding
  the verdict, while every block contains exactly one flush round, so
  the amortized fold cost stays in the statistic instead of hiding
  behind the three delta-free rounds per cadence window,
* truthfulness -- after the run, the head's `metrics` export must show
  exactly the deltas the loop sent (per-worker counter aggregates and
  the cluster poll-histogram count), so the overhead being measured is
  the overhead of telemetry that is actually *true*.

Run:  PYTHONPATH=src python benchmarks/obs_bench.py [--quick]
      PYTHONPATH=src python benchmarks/obs_bench.py --obs-smoke
"""
from __future__ import annotations

import argparse
import statistics
import time
from typing import Dict, Optional

from repro.core import SchedulerConfig, SyndeoCluster
from repro.core.worker import HeadServer


def _noop():
    return None


#: mirror of run_worker's default telemetry cadence (metrics_every):
#: deltas accrue worker-side and ride every k-th poll frame
METRICS_EVERY = 4


class _Arm:
    """One cluster + head driven a poll round at a time: every worker
    sends its result ack (when it has one) and its poll as one batch
    frame; the metrics arm rides its accrued delta sub-op on that same
    frame every METRICS_EVERY-th round."""

    def __init__(self, metrics_on: bool, n_workers: int, n_tasks: int):
        self.metrics_on = metrics_on
        self.n_tasks = n_tasks
        self.cluster = SyndeoCluster(scheduler_config=SchedulerConfig(
            shards=8, enable_speculation=False, heartbeat_timeout=1e9))
        self.head = HeadServer(self.cluster)
        self.head.attach()
        self.wids = [self.head.dispatch({"op": "join", "worker": ""})
                     ["worker"] for _ in range(n_workers)]
        for i in range(n_tasks):
            self.cluster.submit(_noop, name=f"t{i}")
        self.pending: Dict[str, object] = {w: None for w in self.wids}
        self.done = 0
        self.frames = 0
        self.polls = 0
        self.deltas_sent: Dict[str, int] = {w: 0 for w in self.wids}
        # worker-side accrual since the last flush (one counter bump and
        # one histogram observation per poll, run_worker's steady state)
        self.accrued: Dict[str, int] = {w: 0 for w in self.wids}
        self.rounds = 0

    def _delta_sub(self, w: str) -> Dict[str, object]:
        n = self.accrued[w]
        return {"op": "metric_deltas", "worker": w,
                "deltas": {"serves": n},
                "hists": {"syndeo_worker_poll_seconds": {
                    "counts": {"3": n}, "sum": 0.004 * n, "count": n}}}

    def round(self) -> Optional[float]:
        """One poll round across all workers; per-result seconds, or
        None when the round completed no results (the warmup round)."""
        self.rounds += 1
        flush = self.metrics_on and self.rounds % METRICS_EVERY == 0
        results = 0
        t0 = time.perf_counter()
        for w in self.wids:
            prev = self.pending[w]
            ops = []
            if prev is not None:
                ops.append({"op": "result_meta", "task": prev,
                            "worker": w, "size": 128})
            if self.metrics_on:
                self.accrued[w] += 1
                if flush:
                    ops.append(self._delta_sub(w))
                    self.deltas_sent[w] += self.accrued[w]
                    self.accrued[w] = 0
            if ops:
                ops.append({"op": "poll", "worker": w})
                r = self.head.dispatch({"op": "batch", "worker": w,
                                        "ops": ops})
                got = r["replies"][-1]
            else:
                got = self.head.dispatch({"op": "poll", "worker": w})
            self.frames += 1
            self.polls += 1
            if prev is not None:
                self.done += 1
                results += 1
            self.pending[w] = got.get("task")
        dt = time.perf_counter() - t0
        return dt / results if results else None

    def check_truthful(self):
        """The head's export must equal what this loop actually sent.
        Accruals still waiting on the cadence flush first (run_worker's
        exit flush), then the folded aggregates must match exactly."""
        for w in self.wids:
            if self.accrued[w]:
                r = self.head.dispatch(self._delta_sub(w))
                assert r.get("ok"), f"exit flush for {w} failed: {r!r}"
                self.deltas_sent[w] += self.accrued[w]
                self.accrued[w] = 0
        export = self.head.dispatch({"op": "metrics"})
        agg = export["per_worker"]
        for w, n in self.deltas_sent.items():
            got = agg.get(w, {}).get("serves", 0)
            assert got == n, \
                f"head folded {got} serve deltas for {w}, sent {n}"
        want = sum(self.deltas_sent.values())
        got = export["syndeo_worker_poll_count"]
        assert got == want, \
            f"poll histogram count {got} != {want} observations sent"

    def close(self):
        self.head.shutdown()
        self.cluster.shutdown()


def obs_run(n_workers: int = 100,
            n_tasks: int = 12000) -> Dict[str, float]:
    """Drive both arms to completion, alternating one poll round at a
    time; returns the paired-ratio overhead estimate plus per-arm frame
    accounting."""
    off = _Arm(False, n_workers, n_tasks)
    on = _Arm(True, n_workers, n_tasks)
    off_times = []
    on_times = []
    try:
        while off.done < n_tasks and on.done < n_tasks:
            a = off.round()
            b = on.round()
            if a is not None and b is not None:
                off_times.append(a)
                on_times.append(b)
        # one arm may have a round or two of tail left (identical task
        # flow, so in practice they finish together)
        while off.done < n_tasks:
            off.round()
        while on.done < n_tasks:
            on.round()
        on.check_truthful()
        # cadence-aligned blocks: each holds METRICS_EVERY round pairs
        # and therefore exactly one flush round, so the block ratio is
        # the amortized overhead -- a per-round median would land on a
        # delta-free round and hide the fold cost entirely
        ratios = [sum(off_times[i:i + METRICS_EVERY])
                  / sum(on_times[i:i + METRICS_EVERY])
                  for i in range(0, len(off_times) - METRICS_EVERY + 1,
                                 METRICS_EVERY)]
        out = {
            "pairs": float(len(ratios)),
            "ratio_median": statistics.median(ratios),
            "off_results_per_s": len(off_times) / sum(off_times),
            "on_results_per_s": len(on_times) / sum(on_times),
            "off_frames_per_poll": off.frames / max(off.polls, 1),
            "on_frames_per_poll": on.frames / max(on.polls, 1),
        }
    finally:
        off.close()
        on.close()
    assert off.done == n_tasks and on.done == n_tasks
    return out


def print_obs(r: Dict[str, float]):
    print("== observability: piggybacked metric deltas vs bare polls ==")
    print(f"{'arm':>12} {'frames/poll':>12} {'results/s':>10}")
    for name in ("off", "on"):
        print(f"{'metrics-' + name:>12} "
              f"{r[f'{name}_frames_per_poll']:>12.3f} "
              f"{r[f'{name}_results_per_s']:>10.0f}")
    print(f"{'overhead':>12} {1.0 - r['ratio_median']:>11.1%} "
          f"(median of {r['pairs']:.0f} cadence-aligned blocks of "
          f"{METRICS_EVERY} interleaved round pairs)")


def obs_smoke(attempts: int = 3) -> int:
    """CI gate: at 100 workers the metrics-on arm speaks exactly as many
    frames per poll as metrics-off (the deltas piggyback -- zero extra
    wire frames) and keeps >= 95% of the metrics-off result throughput
    by the paired-round median; obs_run itself asserts the folded
    aggregates equal what was sent. The frame gate is exact and never
    retried; the throughput gate gets up to `attempts` runs so one
    noisy-neighbor burst cannot fail CI (a real >5% regression fails
    every attempt)."""
    ok = True
    best = None
    for i in range(attempts):
        r = obs_run()
        print_obs(r)
        if r["on_frames_per_poll"] != r["off_frames_per_poll"]:
            print(f"FAIL: metric deltas cost extra wire frames "
                  f"({r['on_frames_per_poll']:.3f} frames/poll vs "
                  f"{r['off_frames_per_poll']:.3f} bare)")
            ok = False
            break
        best = max(best or 0.0, r["ratio_median"])
        if best >= 0.95:
            break
        print(f"retry {i + 1}: paired overhead "
              f"{1.0 - r['ratio_median']:.1%} over budget")
    if ok and (best is None or best < 0.95):
        print(f"FAIL: metrics-on kept only {best:.1%} of metrics-off "
              f"throughput across {attempts} attempts (need >= 95%)")
        ok = False
    print("\nobs smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--obs-smoke", action="store_true")
    args = ap.parse_args()
    if args.obs_smoke:
        raise SystemExit(obs_smoke())
    if args.quick:
        print_obs(obs_run(n_workers=25, n_tasks=1000))
    else:
        print_obs(obs_run())


if __name__ == "__main__":
    main()
