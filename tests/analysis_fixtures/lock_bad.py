"""known-bad: blocking I/O while holding a lock (SYN-L001)."""
import threading
import time


class Cache:
    def __init__(self, sock):
        self._lock = threading.Lock()
        self.sock = sock
        self.items = {}

    def refresh(self):
        with self._lock:
            data = self.sock.recv(4096)       # direct blocking leaf
            self.items["latest"] = data

    def tick(self):
        with self._lock:
            self._poll()                      # transitive: _poll sleeps

    def _poll(self):
        time.sleep(0.5)
