"""Parameter -> PartitionSpec rules for every architecture family.

Name-based dispatch over the param tree paths that `models/` produce.
Conventions (logical axes; bound to physical axes by `axes.py`):
  * column-parallel (d -> wide):   (..., "fsdp", "model")
  * row-parallel   (wide -> d):    (..., "model", "fsdp")
  * experts: ("expert" = data axis) leading, d_ff over "model" (expert-TP)
  * embeddings: vocab over "model", d over "fsdp"
  * norms / small vectors / convs: replicated

"fsdp" resolves to the DP axes only for archs with cfg.fsdp=True (arctic,
internvl2); otherwise it resolves to () = no sharding. The divisibility
guard in axes.py drops any axis that does not divide the dim (whisper's 6
heads, xlstm's 4 heads, GQA kv-heads < TP, ...).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.axes import _guard_divisibility

# suffix -> logical spec for the trailing (non-stacked) dims
_COL = ("fsdp", "model")      # (d_in, d_out_wide)
_ROW = ("model", "fsdp")      # (d_in_wide, d_out)
_RULES: Dict[str, Tuple] = {
    # dense attention / mlp
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "w1": _COL, "w3": _COL, "w2": _ROW,
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # embeddings
    "tok": ("model", "fsdp"), "out": ("model", "fsdp"),
    # mamba2
    "w_zx": _COL, "w_bc": (None, None), "w_dt": (None, None),
    "w_out": _ROW, "conv_w": (None, None), "conv_b": (None,),
    "A_log": (None,), "D": (None,), "dt_bias": (None,), "norm_w": (None,),
    # xlstm
    "w_up": _COL, "w_down": _ROW,
    "w_q": (None, "model"), "w_k": (None, "model"), "w_v": (None, "model"),
    "w_if": (None, None), "b_if": (None,), "r_gates": (None, None, None),
    "w_gates": _COL, "b_gates": (None,), "w_ff1": _COL, "w_ff2": _ROW,
    # moe
    "router": (None, None),
}
_MOE_RULES = {
    # experts over the in-pod DP axis (EP), d_ff over model (expert-TP),
    # d_model over the pod axis on multi-pod meshes (expert FSDP across
    # pods: "pod_fsdp" resolves to () on a single pod)
    "w1": ("expert", "pod_fsdp", "model"),
    "w3": ("expert", "pod_fsdp", "model"),
    "w2": ("expert", "model", "pod_fsdp"),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return tuple(names)


def logical_spec(path, leaf, cfg: ModelConfig) -> Tuple:
    names = _path_names(path)
    name = names[-1] if names else ""
    in_moe = "moe" in names
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim

    if in_moe and name in _MOE_RULES and "dense" not in names:
        rule = _MOE_RULES[name]
    elif name in _RULES:
        rule = _RULES[name]
    else:
        rule = ()  # norms, scalars -> replicated

    lead = ndim - len(rule)
    assert lead >= 0, (names, ndim, rule)
    return (None,) * lead + tuple(rule)


def param_pspecs(params_shape: Any, cfg: ModelConfig,
                 rules: Dict[str, Tuple[str, ...]]) -> Any:
    """Pytree of PartitionSpec mirroring the params pytree.

    `rules` maps logical names -> physical axes (see axes.single_pod_rules).
    For non-FSDP archs "fsdp" is stripped here.
    """
    eff_rules = dict(rules)
    if not cfg.fsdp:
        eff_rules["fsdp"] = ()

    def resolve_logical(spec):
        out = []
        for ax in spec:
            if ax is None:
                out.append(None)
            else:
                phys = eff_rules.get(ax, ())
                out.append(phys if phys else None)
        return P(*out)

    def per_leaf(path, leaf):
        return resolve_logical(logical_spec(path, leaf, cfg))

    return jax.tree_util.tree_map_with_path(per_leaf, params_shape)


def zero1_extend(pspec: P, shape, mesh: Mesh, dp_axes: Tuple[str, ...]) -> P:
    """ZeRO-1: shard optimizer state over the DP axes by assigning them to
    the first unsharded dim they divide (no-op if none divides)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = [a for a in dp_axes if a in sizes]
    if not dp:
        return pspec
    used = set()
    for e in tuple(pspec):
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    dp = [a for a in dp if a not in used]
    if not dp:
        return pspec
    dp_size = int(np.prod([sizes[a] for a in dp]))
    entries = list(tuple(pspec) + (None,) * (len(shape) - len(tuple(pspec))))
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % dp_size == 0 and dim >= dp_size:
            entries[i] = tuple(dp)
            return P(*entries)
    return pspec


def named_shardings(params_shape: Any, cfg: ModelConfig, mesh: Mesh,
                    rules: Dict[str, Tuple[str, ...]]) -> Any:
    specs = param_pspecs(params_shape, cfg, rules)

    def mk(leaf, spec):
        spec = _guard_divisibility(mesh, leaf.shape, spec)
        return NamedSharding(mesh, spec)

    return jax.tree.map(mk, params_shape, specs)
