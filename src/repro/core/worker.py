"""Containerized node entrypoint (the `%runscript` of the Apptainer image).

`--role head` starts a head: publishes its endpoint via the file rendezvous
(shared FS / bucket mount), serves the task protocol over TCP, and runs the
demo workload if requested. `--role worker` polls the rendezvous, HMAC-
handshakes, then pulls tasks over IP -- the paper's phases 2-4 over real
sockets. Used by the subprocess integration test and by the rendered Slurm /
K8s / GCP artifacts.

Protocol: one JSON envelope per connection (HMAC-sealed, security.py);
payloads are pickled+base64 (the container image pins the code version, so
pickle compatibility holds by construction).
"""
from __future__ import annotations

import argparse
import base64
import json
import pickle
import socket
import socketserver
import threading
import time
import uuid
from typing import Any, Dict, Optional

from repro.core.cluster import SyndeoCluster
from repro.core.object_store import NodeStore
from repro.core.rendezvous import Endpoint, FileRendezvous
from repro.core.scheduler import WorkerInfo
from repro.core.security import Capability, NonceCache, open_sealed, seal
from repro.core.task_graph import TaskState


def _enc(obj: Any) -> str:
    return base64.b64encode(pickle.dumps(obj)).decode()


def _dec(blob: str) -> Any:
    return pickle.loads(base64.b64decode(blob))


def _request(host: str, port: int, token: str, msg: Dict[str, Any],
             timeout: float = 10.0,
             nonce_cache: Optional[NonceCache] = None) -> Dict[str, Any]:
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.sendall((json.dumps(seal(token, msg)) + "\n").encode())
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(1 << 20)
            if not chunk:
                break
            buf += chunk
    return open_sealed(token, json.loads(buf.decode()),
                       nonce_cache=nonce_cache)


class HeadServer:
    """TCP face of a SyndeoCluster (pull-based workers)."""

    def __init__(self, cluster: SyndeoCluster, host: str = "127.0.0.1",
                 port: int = 0):
        self.cluster = cluster
        self._outbox: Dict[str, list] = {}
        # bounded seen-nonce set: a captured worker envelope cannot be
        # replayed inside the freshness window (it would need a fresh nonce,
        # and the nonce is under the MAC)
        self._nonces = NonceCache()
        head = self

        class Handler(socketserver.StreamRequestHandler):
            def handle(self):
                line = self.rfile.readline()
                try:
                    msg = open_sealed(cluster.token,
                                      json.loads(line.decode()),
                                      nonce_cache=head._nonces)
                    reply = head.dispatch(msg)
                except Exception as e:  # noqa: BLE001
                    reply = {"ok": False, "error": str(e)}
                self.wfile.write(
                    (json.dumps(seal(cluster.token, reply)) + "\n").encode())

        self.server = socketserver.ThreadingTCPServer((host, port), Handler,
                                                      bind_and_activate=True)
        self.server.daemon_threads = True
        self.port = self.server.server_address[1]
        self._thread = threading.Thread(target=self.server.serve_forever,
                                        daemon=True)
        self._thread.start()
        # re-publish the rendezvous with the real TCP port
        cluster.rendezvous.publish(Endpoint(host, self.port,
                                            cluster.cluster_id, cluster.token))

    # head-side handling ------------------------------------------------------

    def dispatch(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        op = msg.get("op")
        c = self.cluster
        if op == "join":
            wid = msg.get("worker") or f"tcp-{uuid.uuid4().hex[:6]}"
            self._outbox.setdefault(wid, [])
            store = NodeStore(wid)  # head-side proxy store for this worker
            c.store.register_node(store)
            with c._lock:
                c.scheduler.add_worker(
                    WorkerInfo(wid, msg.get("resources", {"cpu": 1.0})))
            return {"ok": True, "worker": wid}
        if op == "poll":
            wid = msg["worker"]
            with c._lock:
                c.scheduler.heartbeat(wid)
                w = c.scheduler.workers.get(wid)
                draining = bool(w and w.draining)
            box = self._outbox.get(wid, [])
            if not box:
                # a drained worker with an empty queue may exit: the head
                # finishes the drain once migrations land and tasks stop
                return {"ok": True, "task": None, "draining": draining}
            tid = box.pop(0)
            with c._lock:
                task = c.scheduler.graph.tasks[tid]
                tenant = task.spec.tenant_id
                try:
                    # deps are resolved head-side *as the task's tenant*: a
                    # task whose deps point at another tenant's objects
                    # fails here -- as a *task failure*, not a stranded
                    # RUNNING task (the worker just keeps polling)
                    payload = _enc(
                        (task.spec.fn, task.spec.args, task.spec.kwargs,
                         [c.store.get(
                             "head", d,
                             capability=Capability.grant_for_tenant(
                                 c.token, tenant, d.id, "get"))
                          for d in task.deps]))
                except Exception as e:  # noqa: BLE001
                    c.scheduler.on_task_failed(
                        tid, f"{type(e).__name__}: {e}", worker_id=wid)
                    ev = c._futures.get(tid)
                    if ev:
                        ev.set()
                    return {"ok": True, "task": None, "draining": draining}
            return {"ok": True, "task": tid, "payload": payload,
                    "tenant": tenant, "draining": draining}
        if op == "result":
            tid, wid = msg["task"], msg["worker"]
            value = _dec(msg["payload"])
            with c._lock:
                task = c.scheduler.graph.tasks.get(tid)
                tenant = task.spec.tenant_id if task else "default"
            try:
                ref = c.store.put("head", value, producer_task=tid,
                                  ref_id=f"obj-{tid}", tenant=tenant)
            except Exception as e:  # noqa: BLE001 -- e.g. quota reject: the
                # task must *fail visibly*, not sit RUNNING forever
                with c._lock:
                    c.scheduler.on_task_failed(
                        tid, f"{type(e).__name__}: {e}", worker_id=wid)
                ev = c._futures.get(tid)
                if ev:
                    ev.set()
                return {"ok": True, "stored": False}
            with c._lock:
                c.scheduler.on_task_finished(tid, ref, worker_id=wid)
            ev = c._futures.get(tid)
            if ev:
                ev.set()
            return {"ok": True}
        if op == "error":
            with c._lock:
                c.scheduler.on_task_failed(msg["task"], msg["err"],
                                           worker_id=msg.get("worker"))
            return {"ok": True}
        if op == "drain":
            # eviction notice for a remote worker: the outer resource
            # manager (or an operator) asks the head to retire this node
            wid = msg["worker"]
            with c._lock:
                ok = c.scheduler.begin_drain(wid, msg.get("deadline_s"))
            return {"ok": ok, "worker": wid}
        if op == "drain_status":
            wid = msg["worker"]
            with c._lock:
                complete = c.scheduler.drain_complete(wid)
                if complete:
                    c.scheduler.finish_drain(wid)
            return {"ok": True, "worker": wid, "complete": complete}
        if op == "stats":
            with c._lock:
                return {"ok": True, "stats": dict(c.scheduler.stats),
                        "tenants": c.scheduler.tenant_shares()}
        if op == "metrics":
            # the scaling signals the K8s custom-metrics adapter republishes
            # for the HorizontalPodAutoscaler (backends/kubernetes.py)
            with c._lock:
                workers = [w for w in c.scheduler.workers.values() if w.alive]
                busy = sum(1 for w in workers if w.running)
                backlog = sum(
                    1 for t in c.scheduler.graph.tasks.values()
                    if t.state in (TaskState.READY, TaskState.PENDING))
                by_tenant = c.scheduler.backlog_by_tenant()
            n = max(len(workers), 1)
            return {"ok": True, "workers": len(workers), "busy": busy,
                    "backlog": backlog,
                    "syndeo_backlog_per_worker": backlog / n,
                    "syndeo_busy_fraction": busy / n,
                    "backlog_by_tenant": by_tenant}
        return {"ok": False, "error": f"unknown op {op!r}"}

    def launch(self, task, worker_id: str):
        self._outbox.setdefault(worker_id, []).append(task.id)

    def attach(self):
        """Route scheduler launches for tcp- workers through the outbox."""
        orig = self.cluster.scheduler.launch_fn

        def launch(task, worker_id):
            if worker_id.startswith("tcp-") or worker_id in self._outbox:
                self.launch(task, worker_id)
            else:
                orig(task, worker_id)
        self.cluster.scheduler.launch_fn = launch

    def shutdown(self):
        self.server.shutdown()


def run_worker(rendezvous_dir: str, cluster_id: str, worker_id: str = "",
               max_idle_s: float = 30.0):
    rdv = FileRendezvous(rendezvous_dir)
    ep = rdv.wait(cluster_id, timeout=60.0)
    token = ep.token
    nonces = NonceCache()        # head replies are replay-protected too
    joined = _request(ep.host, ep.port, token,
                      {"op": "join", "worker": worker_id,
                       "resources": {"cpu": 1.0}}, nonce_cache=nonces)
    wid = joined["worker"]
    idle_since = time.monotonic()
    while time.monotonic() - idle_since < max_idle_s:
        got = _request(ep.host, ep.port, token, {"op": "poll", "worker": wid},
                       nonce_cache=nonces)
        tid = got.get("task")
        if tid is None:
            if got.get("draining"):
                # exit only when the head confirms the drain finished --
                # a cancelled drain (backlog returned) keeps us serving
                status = _request(ep.host, ep.port, token,
                                  {"op": "drain_status", "worker": wid},
                                  nonce_cache=nonces)
                if status.get("complete"):
                    return
            time.sleep(0.05)
            continue
        idle_since = time.monotonic()
        fn, args, kwargs, deps = _dec(got["payload"])
        try:
            out = fn(*args, *deps, **kwargs)
            _request(ep.host, ep.port, token,
                     {"op": "result", "task": tid, "worker": wid,
                      "payload": _enc(out)}, nonce_cache=nonces)
        except Exception as e:  # noqa: BLE001
            _request(ep.host, ep.port, token,
                     {"op": "error", "task": tid, "worker": wid,
                      "err": f"{type(e).__name__}: {e}"}, nonce_cache=nonces)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=["head", "worker"], required=True)
    ap.add_argument("--rendezvous", required=True)
    ap.add_argument("--cluster-id", required=True)
    ap.add_argument("--worker-id", default="")
    ap.add_argument("--max-idle-s", type=float, default=30.0)
    args = ap.parse_args()
    if args.role == "worker":
        run_worker(args.rendezvous, args.cluster_id, args.worker_id,
                   args.max_idle_s)
    else:
        rdv = FileRendezvous(args.rendezvous)
        cluster = SyndeoCluster(rendezvous=rdv)
        cluster.cluster_id = args.cluster_id
        server = HeadServer(cluster)
        server.attach()
        print(f"head up on port {server.port}", flush=True)
        try:
            while True:
                time.sleep(1.0)
                cluster.health_check()
        except KeyboardInterrupt:
            server.shutdown()
            cluster.shutdown()


if __name__ == "__main__":
    main()
